//! HTTP load generator for the gateway's `/v1/completions` endpoint.
//!
//! Two arrival modes:
//! - **closed loop** (default): `concurrency` client threads each hold
//!   one keep-alive connection and fire the next request as soon as the
//!   previous response lands — so `concurrency` ≙ open connections;
//! - **open loop** (`rate: Some(r)`): request `i` is *due* at
//!   `t0 + i/r` regardless of how fast earlier responses came back,
//!   which is what exposes queueing collapse under overload.
//!
//! With `stream: true` requests go out as SSE (`"stream": true`) and
//! TTFT is measured at the first `data:` event — the first byte of
//! generated text, not the end of the response.
//!
//! Results fold into the same [`Report`] table the simulator prints —
//! so `bfio sim`, `bfio serve`, and a live gateway are comparable line
//! by line.  [`sweep`] repeats one workload across a `--connections`
//! ladder and yields the `BENCH_gateway.json` rows.
//!
//! Workload shapes come either from a recorded trace (`--trace`, the
//! JSONL format of [`crate::workload::trace`]) or from a seeded uniform
//! sampler around `--prompt-tokens` / `--max-tokens`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics::Report;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::workload::Request;

use super::http::{http_call, sse_call, HttpClient};

/// Load-generator configuration.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    /// Gateway authority, `host:port`.
    pub authority: String,
    /// Concurrent closed-loop clients.
    pub concurrency: usize,
    /// Total requests to issue.
    pub requests: usize,
    /// Mean prompt length (tokens) for the synthetic sampler.
    pub prompt_tokens: usize,
    /// Mean decode budget (tokens) for the synthetic sampler.
    pub max_tokens: u64,
    pub seed: u64,
    /// Replay these request shapes instead of sampling (cycled if
    /// shorter than `requests`).
    pub trace: Option<Vec<Request>>,
    /// Request SSE streaming (`"stream": true`) and measure TTFT at
    /// the first `data:` event.
    pub stream: bool,
    /// Open-loop arrival rate in requests/s; `None` = closed loop.
    pub rate: Option<f64>,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            authority: "127.0.0.1:8080".to_string(),
            concurrency: 8,
            requests: 64,
            prompt_tokens: 32,
            max_tokens: 16,
            seed: 0,
            trace: None,
            stream: false,
            rate: None,
        }
    }
}

/// One successful completion as observed by a client thread.
#[derive(Clone, Debug)]
struct PerRequest {
    worker: usize,
    tokens: u64,
    /// Client-side wall latency.
    latency_s: f64,
    /// Client-side time to first token: first SSE `data:` event for
    /// streamed requests, `None` for non-streamed ones.
    ttft_s: Option<f64>,
    /// Server-reported (backend clock) figures.
    tpot_s: f64,
    queue_wait_s: f64,
}

/// What one client-observed request came back as.
enum Outcome {
    Done(PerRequest),
    /// Gateway shed the request (429 at the admission watermark or 503
    /// at the connection cap / during drain / after retry exhaustion).
    Shed(String),
    Failed(String),
}

/// Aggregate outcome of one load-generation run.
#[derive(Clone, Debug, Default)]
pub struct LoadGenResult {
    pub completed: usize,
    /// Transport / protocol failures (not sheds).
    pub errors: usize,
    /// 429/503 sheds — the gateway's graceful-degradation path,
    /// counted separately from hard errors.
    pub sheds: usize,
    /// Server-side completion retries during this run
    /// (`bfio_gateway_retries_total` diff).
    pub retries: u64,
    /// Client wall time for the whole run.
    pub wall_s: f64,
    /// Total generated tokens (server-reported).
    pub tokens: u64,
    pub latencies_s: Vec<f64>,
    /// Time-to-first-token samples (streamed requests only).
    pub ttfts_s: Vec<f64>,
    pub tpots_s: Vec<f64>,
    pub queue_waits_s: Vec<f64>,
    /// Completions per worker id.
    pub per_worker: BTreeMap<usize, u64>,
    /// Raw `/metrics` snapshots taken just before and just after the
    /// run, so [`fetch_report`] can diff server counters and report
    /// *this run's* steps/energy/imbalance even against a gateway that
    /// has already served other traffic.
    pub metrics_before: String,
    pub metrics_after: String,
}

/// Issue `cfg.requests` completions over HTTP and gather the results.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadGenResult> {
    if cfg.requests == 0 {
        bail!("--requests must be >= 1");
    }
    // (prompt_len, decode_len) per request.
    let items: Vec<(usize, u64)> = match &cfg.trace {
        Some(t) => {
            if t.is_empty() {
                bail!("trace is empty");
            }
            (0..cfg.requests)
                .map(|i| {
                    let r = &t[i % t.len()];
                    (r.prefill.max(1.0) as usize, r.decode_len.max(1))
                })
                .collect()
        }
        None => {
            let mut rng = Rng::new(cfg.seed);
            (0..cfg.requests)
                .map(|_| {
                    (
                        1 + rng.below_usize(cfg.prompt_tokens.max(1) * 2),
                        1 + rng.below(cfg.max_tokens.max(1) * 2),
                    )
                })
                .collect()
        }
    };
    let items = Arc::new(items);
    let cursor = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = channel::<Outcome>();

    let metrics_before = scrape_metrics(&cfg.authority);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for _ in 0..cfg.concurrency.max(1) {
        let items = Arc::clone(&items);
        let cursor = Arc::clone(&cursor);
        let tx = tx.clone();
        let authority = cfg.authority.clone();
        let stream = cfg.stream;
        let rate = cfg.rate;
        handles.push(std::thread::spawn(move || {
            // One keep-alive connection per client thread — this is
            // what a loadgen "connection" means.
            let mut client = HttpClient::new(&authority);
            loop {
                let i = cursor.fetch_add(1, Ordering::SeqCst);
                if i >= items.len() {
                    break;
                }
                if let Some(r) = rate {
                    // Open loop: request i is due at t0 + i/r no
                    // matter how fast earlier responses came back.
                    let due = t0 + Duration::from_secs_f64(i as f64 / r.max(1e-9));
                    let now = Instant::now();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                }
                let (plen, dec) = items[i];
                let outcome = one_request(&mut client, &authority, i, plen, dec, stream);
                if tx.send(outcome).is_err() {
                    break;
                }
            }
        }));
    }
    drop(tx);

    let mut res = LoadGenResult::default();
    for outcome in rx {
        match outcome {
            Outcome::Done(p) => {
                res.completed += 1;
                res.tokens += p.tokens;
                res.latencies_s.push(p.latency_s);
                if let Some(t) = p.ttft_s {
                    res.ttfts_s.push(t);
                }
                res.tpots_s.push(p.tpot_s);
                res.queue_waits_s.push(p.queue_wait_s);
                *res.per_worker.entry(p.worker).or_insert(0) += 1;
            }
            Outcome::Shed(e) => {
                res.sheds += 1;
                eprintln!("loadgen: shed: {e}");
            }
            Outcome::Failed(e) => {
                res.errors += 1;
                eprintln!("loadgen: {e}");
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    res.wall_s = t0.elapsed().as_secs_f64();
    res.metrics_before = metrics_before;
    res.metrics_after = scrape_metrics(&cfg.authority);
    let retries_before =
        prom_value(&res.metrics_before, "bfio_gateway_retries_total").unwrap_or(0.0);
    let retries_after =
        prom_value(&res.metrics_after, "bfio_gateway_retries_total").unwrap_or(0.0);
    res.retries = (retries_after - retries_before).max(0.0) as u64;
    Ok(res)
}

/// Best-effort `/metrics` scrape (empty string when unreachable —
/// counter diffs then fall back to zero baselines).
fn scrape_metrics(authority: &str) -> String {
    http_call(authority, "GET", "/metrics", None)
        .ok()
        .and_then(|r| r.body_str().map(str::to_string).ok())
        .unwrap_or_default()
}

fn one_request(
    client: &mut HttpClient,
    authority: &str,
    i: usize,
    plen: usize,
    dec: u64,
    stream: bool,
) -> Outcome {
    let r = if stream {
        one_request_stream(authority, plen, dec)
    } else {
        one_request_blocking(client, plen, dec)
    };
    match r {
        Ok(out) => out,
        Err(e) => Outcome::Failed(format!("request {i}: {e:#}")),
    }
}

fn request_body(plen: usize, dec: u64, stream: bool) -> String {
    let mut fields = vec![
        (
            "prompt",
            Json::Arr((0..plen).map(|j| Json::Num((j % 997) as f64)).collect()),
        ),
        ("max_tokens", json::num(dec as f64)),
    ];
    if stream {
        fields.push(("stream", Json::Bool(true)));
    }
    json::obj(fields).to_string()
}

/// Pull `(worker, tokens, tpot_s, queue_wait_s)` from a completion (or
/// final SSE chunk) JSON object — both carry the same usage/bfio shape.
fn parse_done(
    v: &Json,
    latency_s: f64,
    ttft_s: Option<f64>,
) -> Result<PerRequest> {
    let bfio = v.get("bfio").context("response missing bfio block")?;
    let field = |k: &str| -> Result<f64> {
        bfio.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("response missing bfio.{k}"))
    };
    let tokens = v
        .get("usage")
        .and_then(|u| u.get("completion_tokens"))
        .and_then(Json::as_u64)
        .context("response missing usage.completion_tokens")?;
    Ok(PerRequest {
        worker: field("worker")? as usize,
        tokens,
        latency_s,
        ttft_s,
        tpot_s: field("tpot_s")?,
        queue_wait_s: field("queue_wait_s")?,
    })
}

fn one_request_blocking(client: &mut HttpClient, plen: usize, dec: u64) -> Result<Outcome> {
    let body = request_body(plen, dec, false);
    let t0 = Instant::now();
    let resp = client.call("POST", "/v1/completions", Some(&body))?;
    let latency_s = t0.elapsed().as_secs_f64();
    if resp.status == 503 || resp.status == 429 {
        // Graceful-degradation shed — not a protocol failure.
        return Ok(Outcome::Shed(format!(
            "status={} retry-after={} {}",
            resp.status,
            resp.header("Retry-After").unwrap_or("?"),
            resp.body_str().unwrap_or("<binary>"),
        )));
    }
    if resp.status != 200 {
        bail!("status {}: {}", resp.status, resp.body_str().unwrap_or("<binary>"));
    }
    let v = Json::parse(resp.body_str()?).map_err(|e| anyhow!("bad response json: {e}"))?;
    Ok(Outcome::Done(parse_done(&v, latency_s, None)?))
}

fn one_request_stream(authority: &str, plen: usize, dec: u64) -> Result<Outcome> {
    let body = request_body(plen, dec, true);
    let t0 = Instant::now();
    let res = sse_call(authority, "/v1/completions", &body)?;
    let latency_s = t0.elapsed().as_secs_f64();
    if res.status == 503 || res.status == 429 {
        let retry_after = res
            .headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case("retry-after"))
            .map(|(_, v)| v.as_str())
            .unwrap_or("?");
        return Ok(Outcome::Shed(format!(
            "status={} retry-after={} {}",
            res.status,
            retry_after,
            String::from_utf8_lossy(&res.body),
        )));
    }
    if res.status != 200 {
        bail!("status {}: {}", res.status, String::from_utf8_lossy(&res.body));
    }
    if !res.done {
        bail!("stream ended without data: [DONE] terminator");
    }
    let ttft_s = res
        .events
        .first()
        .map(|(_, at)| at.duration_since(t0).as_secs_f64());
    // The final pre-[DONE] chunk carries usage + bfio.
    let (last, _) = res.events.last().context("stream carried no data events")?;
    let v = Json::parse(last).map_err(|e| anyhow!("bad final chunk json: {e}"))?;
    Ok(Outcome::Done(parse_done(&v, latency_s, ttft_s)?))
}

/// Extract one sample value from a Prometheus exposition document.
/// Matches `name 1.5` and `name{labels} 1.5` lines.
pub fn prom_value(text: &str, name: &str) -> Option<f64> {
    for line in text.lines() {
        if line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix(name) {
            if !(rest.starts_with(' ') || rest.starts_with('{')) {
                continue; // longer metric name sharing the prefix
            }
            if let Some(tok) = rest.rsplit(' ').next() {
                if let Ok(x) = tok.trim().parse::<f64>() {
                    return Some(x);
                }
            }
        }
    }
    None
}

/// Combine client-side measurements with the gateway's `/metrics` and
/// `/v0/workers` into the simulator's [`Report`] shape.  Server-side
/// counters are *diffed* against the pre-run snapshot, so the report
/// covers this run only, not the gateway's lifetime.  Returns
/// `(policy_name, report)`.
pub fn fetch_report(authority: &str, res: &LoadGenResult) -> Result<(String, Report)> {
    let workers = http_call(authority, "GET", "/v0/workers", None)?;
    let wj = Json::parse(workers.body_str()?)
        .map_err(|e| anyhow!("bad /v0/workers json: {e}"))?;
    let policy = wj
        .get("policy")
        .and_then(Json::as_str)
        .unwrap_or("unknown")
        .to_string();

    let before = |name: &str| prom_value(&res.metrics_before, name).unwrap_or(0.0);
    let after = |name: &str| prom_value(&res.metrics_after, name).unwrap_or(0.0);
    let steps_b = before("bfio_steps_total");
    let steps_a = after("bfio_steps_total");
    let steps_run = (steps_a - steps_b).max(0.0);
    let steps = steps_run as u64;
    let energy_j =
        (after("bfio_energy_joules") - before("bfio_energy_joules")).max(0.0);
    // avg = imb_sum/steps, so the run's average recovers exactly from
    // the two (average, steps) pairs.
    let imb_sum_run =
        after("bfio_avg_imbalance") * steps_a - before("bfio_avg_imbalance") * steps_b;
    let avg_imbalance = if steps_run > 0.0 {
        (imb_sum_run / steps_run).max(0.0)
    } else {
        0.0
    };

    let report = Report {
        steps,
        avg_imbalance,
        mean_idle_fraction: 0.0, // not exposed per-step over HTTP
        throughput_tps: if res.wall_s > 0.0 {
            res.tokens as f64 / res.wall_s
        } else {
            0.0
        },
        tpot_s: stats::mean(&res.tpots_s),
        tpot_p99_s: if res.tpots_s.is_empty() {
            0.0
        } else {
            stats::percentile(&res.tpots_s, 99.0)
        },
        // Ratio gauge, not a diffable counter: this is the gateway's
        // lifetime goodput (exact for a fresh gateway, the CI case).
        slo_goodput: after("bfio_slo_goodput_ratio"),
        mean_queue_wait_s: stats::mean(&res.queue_waits_s),
        completed: res.completed as u64,
        completions: Vec::new(),
        total_tokens: res.tokens as f64,
        wall_time_s: res.wall_s,
        sync_energy_j: 0.0,
        total_energy_j: energy_j,
        energy_useful_j: (after("bfio_energy_useful_joules")
            - before("bfio_energy_useful_joules"))
        .max(0.0),
        energy_idle_j: (after("bfio_energy_idle_joules")
            - before("bfio_energy_idle_joules"))
        .max(0.0),
        energy_correction_j: (after("bfio_energy_correction_joules")
            - before("bfio_energy_correction_joules"))
        .max(0.0),
        eta_sum: 0.0,
        total_workload: 0.0,
        imb_tot: 0.0,
        obs: Default::default(),
        series: None,
    };
    Ok((policy, report))
}

/// Human summary of one run (client-side view + per-worker spread).
pub fn print_summary(cfg: &LoadGenConfig, res: &LoadGenResult) {
    println!(
        "loadgen: {} ok, {} shed, {} errors over {} clients in {:.3}s  \
         ({:.1} req/s, {:.1} tok/s, {} server retries)",
        res.completed,
        res.sheds,
        res.errors,
        cfg.concurrency,
        res.wall_s,
        res.completed as f64 / res.wall_s.max(1e-9),
        res.tokens as f64 / res.wall_s.max(1e-9),
        res.retries,
    );
    if !res.latencies_s.is_empty() {
        println!(
            "  wall latency: mean {:.4}s  p99 {:.4}s   server tpot: mean {:.4}s",
            stats::mean(&res.latencies_s),
            stats::percentile(&res.latencies_s, 99.0),
            stats::mean(&res.tpots_s),
        );
    }
    if !res.ttfts_s.is_empty() {
        println!(
            "  ttft (first SSE byte): mean {:.4}s  p50 {:.4}s  p99 {:.4}s",
            stats::mean(&res.ttfts_s),
            stats::percentile(&res.ttfts_s, 50.0),
            stats::percentile(&res.ttfts_s, 99.0),
        );
    }
    let spread: Vec<String> = res
        .per_worker
        .iter()
        .map(|(w, n)| format!("{w}:{n}"))
        .collect();
    println!("  per-worker completions: {}", spread.join(" "));
}

/// One row of a `--connections` sweep (the `BENCH_gateway.json` shape).
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub connections: usize,
    pub completed: usize,
    pub sheds: usize,
    pub errors: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub throughput_tps: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tpot_p50_s: f64,
    pub tpot_p99_s: f64,
}

/// Run the same workload once per connection count.  Connections ==
/// concurrency: each client thread holds one keep-alive socket.  For
/// non-streamed runs TTFT falls back to the full wall latency (first
/// byte and last byte arrive together).
pub fn sweep(cfg: &LoadGenConfig, connections: &[usize]) -> Result<Vec<SweepRow>> {
    let mut rows = Vec::new();
    for &conns in connections {
        let run_cfg = LoadGenConfig { concurrency: conns.max(1), ..cfg.clone() };
        let res = run(&run_cfg)?;
        let ttfts: &[f64] = if res.ttfts_s.is_empty() { &res.latencies_s } else { &res.ttfts_s };
        rows.push(SweepRow {
            connections: conns,
            completed: res.completed,
            sheds: res.sheds,
            errors: res.errors,
            wall_s: res.wall_s,
            throughput_rps: res.completed as f64 / res.wall_s.max(1e-9),
            throughput_tps: res.tokens as f64 / res.wall_s.max(1e-9),
            ttft_p50_s: pct(ttfts, 50.0),
            ttft_p99_s: pct(ttfts, 99.0),
            tpot_p50_s: pct(&res.tpots_s, 50.0),
            tpot_p99_s: pct(&res.tpots_s, 99.0),
        });
    }
    Ok(rows)
}

fn pct(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        stats::percentile(xs, p)
    }
}

/// Table view of a sweep, one line per connection count.
pub fn print_sweep(rows: &[SweepRow]) {
    println!(
        "{:>6} {:>7} {:>5} {:>5} {:>8} {:>9} {:>10} {:>10} {:>10} {:>10}",
        "conns", "ok", "shed", "err", "req/s", "tok/s", "ttft_p50", "ttft_p99",
        "tpot_p50", "tpot_p99"
    );
    for r in rows {
        println!(
            "{:>6} {:>7} {:>5} {:>5} {:>8.1} {:>9.1} {:>10.4} {:>10.4} {:>10.4} {:>10.4}",
            r.connections,
            r.completed,
            r.sheds,
            r.errors,
            r.throughput_rps,
            r.throughput_tps,
            r.ttft_p50_s,
            r.ttft_p99_s,
            r.tpot_p50_s,
            r.tpot_p99_s,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prom_value_parses_labelled_and_bare() {
        let text = "\
# HELP bfio_imbalance x
# TYPE bfio_imbalance gauge
bfio_imbalance 12.5
bfio_requests_total{policy=\"jsq\"} 7
bfio_imbalance_extra 99
";
        assert_eq!(prom_value(text, "bfio_imbalance"), Some(12.5));
        assert_eq!(prom_value(text, "bfio_requests_total"), Some(7.0));
        assert_eq!(prom_value(text, "bfio_missing"), None);
        // prefix must not match the longer name
        assert_eq!(prom_value(text, "bfio_imbalance_extra"), Some(99.0));
    }

    #[test]
    fn zero_requests_rejected() {
        let cfg = LoadGenConfig { requests: 0, ..LoadGenConfig::default() };
        assert!(run(&cfg).is_err());
    }
}
