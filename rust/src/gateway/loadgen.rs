//! Closed-loop HTTP load generator: `concurrency` client threads each
//! replay requests against a gateway's `/v1/completions` endpoint as
//! fast as responses come back, then the per-policy results are folded
//! into the same [`Report`] table the simulator prints — so `bfio sim`,
//! `bfio serve`, and a live gateway are comparable line by line.
//!
//! Workload shapes come either from a recorded trace (`--trace`, the
//! JSONL format of [`crate::workload::trace`]) or from a seeded uniform
//! sampler around `--prompt-tokens` / `--max-tokens`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics::Report;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::workload::Request;

use super::http::http_call;

/// Load-generator configuration.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    /// Gateway authority, `host:port`.
    pub authority: String,
    /// Concurrent closed-loop clients.
    pub concurrency: usize,
    /// Total requests to issue.
    pub requests: usize,
    /// Mean prompt length (tokens) for the synthetic sampler.
    pub prompt_tokens: usize,
    /// Mean decode budget (tokens) for the synthetic sampler.
    pub max_tokens: u64,
    pub seed: u64,
    /// Replay these request shapes instead of sampling (cycled if
    /// shorter than `requests`).
    pub trace: Option<Vec<Request>>,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            authority: "127.0.0.1:8080".to_string(),
            concurrency: 8,
            requests: 64,
            prompt_tokens: 32,
            max_tokens: 16,
            seed: 0,
            trace: None,
        }
    }
}

/// One successful completion as observed by a client thread.
#[derive(Clone, Debug)]
struct PerRequest {
    worker: usize,
    tokens: u64,
    /// Client-side wall latency.
    latency_s: f64,
    /// Server-reported (backend clock) figures.
    tpot_s: f64,
    queue_wait_s: f64,
}

/// What one client-observed request came back as.
enum Outcome {
    Done(PerRequest),
    /// Gateway shed the request (503 after exhausting its retries).
    Shed(String),
    Failed(String),
}

/// Aggregate outcome of one load-generation run.
#[derive(Clone, Debug, Default)]
pub struct LoadGenResult {
    pub completed: usize,
    /// Transport / protocol failures (not sheds).
    pub errors: usize,
    /// 503 sheds — the gateway's graceful-degradation path, counted
    /// separately from hard errors.
    pub sheds: usize,
    /// Server-side completion retries during this run
    /// (`bfio_gateway_retries_total` diff).
    pub retries: u64,
    /// Client wall time for the whole run.
    pub wall_s: f64,
    /// Total generated tokens (server-reported).
    pub tokens: u64,
    pub latencies_s: Vec<f64>,
    pub tpots_s: Vec<f64>,
    pub queue_waits_s: Vec<f64>,
    /// Completions per worker id.
    pub per_worker: BTreeMap<usize, u64>,
    /// Raw `/metrics` snapshots taken just before and just after the
    /// run, so [`fetch_report`] can diff server counters and report
    /// *this run's* steps/energy/imbalance even against a gateway that
    /// has already served other traffic.
    pub metrics_before: String,
    pub metrics_after: String,
}

/// Issue `cfg.requests` completions over HTTP and gather the results.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadGenResult> {
    if cfg.requests == 0 {
        bail!("--requests must be >= 1");
    }
    // (prompt_len, decode_len) per request.
    let items: Vec<(usize, u64)> = match &cfg.trace {
        Some(t) => {
            if t.is_empty() {
                bail!("trace is empty");
            }
            (0..cfg.requests)
                .map(|i| {
                    let r = &t[i % t.len()];
                    (r.prefill.max(1.0) as usize, r.decode_len.max(1))
                })
                .collect()
        }
        None => {
            let mut rng = Rng::new(cfg.seed);
            (0..cfg.requests)
                .map(|_| {
                    (
                        1 + rng.below_usize(cfg.prompt_tokens.max(1) * 2),
                        1 + rng.below(cfg.max_tokens.max(1) * 2),
                    )
                })
                .collect()
        }
    };
    let items = Arc::new(items);
    let cursor = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = channel::<Outcome>();

    let metrics_before = scrape_metrics(&cfg.authority);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for _ in 0..cfg.concurrency.max(1) {
        let items = Arc::clone(&items);
        let cursor = Arc::clone(&cursor);
        let tx = tx.clone();
        let authority = cfg.authority.clone();
        handles.push(std::thread::spawn(move || loop {
            let i = cursor.fetch_add(1, Ordering::SeqCst);
            if i >= items.len() {
                break;
            }
            let (plen, dec) = items[i];
            let outcome = one_request(&authority, i, plen, dec);
            if tx.send(outcome).is_err() {
                break;
            }
        }));
    }
    drop(tx);

    let mut res = LoadGenResult::default();
    for outcome in rx {
        match outcome {
            Outcome::Done(p) => {
                res.completed += 1;
                res.tokens += p.tokens;
                res.latencies_s.push(p.latency_s);
                res.tpots_s.push(p.tpot_s);
                res.queue_waits_s.push(p.queue_wait_s);
                *res.per_worker.entry(p.worker).or_insert(0) += 1;
            }
            Outcome::Shed(e) => {
                res.sheds += 1;
                eprintln!("loadgen: shed: {e}");
            }
            Outcome::Failed(e) => {
                res.errors += 1;
                eprintln!("loadgen: {e}");
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    res.wall_s = t0.elapsed().as_secs_f64();
    res.metrics_before = metrics_before;
    res.metrics_after = scrape_metrics(&cfg.authority);
    let retries_before =
        prom_value(&res.metrics_before, "bfio_gateway_retries_total").unwrap_or(0.0);
    let retries_after =
        prom_value(&res.metrics_after, "bfio_gateway_retries_total").unwrap_or(0.0);
    res.retries = (retries_after - retries_before).max(0.0) as u64;
    Ok(res)
}

/// Best-effort `/metrics` scrape (empty string when unreachable —
/// counter diffs then fall back to zero baselines).
fn scrape_metrics(authority: &str) -> String {
    http_call(authority, "GET", "/metrics", None)
        .ok()
        .and_then(|r| r.body_str().map(str::to_string).ok())
        .unwrap_or_default()
}

fn one_request(authority: &str, i: usize, plen: usize, dec: u64) -> Outcome {
    match one_request_inner(authority, plen, dec) {
        Ok(out) => out,
        Err(e) => Outcome::Failed(format!("request {i}: {e:#}")),
    }
}

fn one_request_inner(authority: &str, plen: usize, dec: u64) -> Result<Outcome> {
    let body = json::obj(vec![
        (
            "prompt",
            Json::Arr((0..plen).map(|j| Json::Num((j % 997) as f64)).collect()),
        ),
        ("max_tokens", json::num(dec as f64)),
    ])
    .to_string();
    let t0 = Instant::now();
    let resp = http_call(authority, "POST", "/v1/completions", Some(&body))?;
    let latency_s = t0.elapsed().as_secs_f64();
    if resp.status == 503 {
        // Graceful-degradation shed — not a protocol failure.
        return Ok(Outcome::Shed(format!(
            "retry-after={} {}",
            resp.header("Retry-After").unwrap_or("?"),
            resp.body_str().unwrap_or("<binary>"),
        )));
    }
    if resp.status != 200 {
        bail!("status {}: {}", resp.status, resp.body_str().unwrap_or("<binary>"));
    }
    let v = Json::parse(resp.body_str()?).map_err(|e| anyhow!("bad response json: {e}"))?;
    let bfio = v.get("bfio").context("response missing bfio block")?;
    let field = |k: &str| -> Result<f64> {
        bfio.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("response missing bfio.{k}"))
    };
    let tokens = v
        .get("usage")
        .and_then(|u| u.get("completion_tokens"))
        .and_then(Json::as_u64)
        .context("response missing usage.completion_tokens")?;
    Ok(Outcome::Done(PerRequest {
        worker: field("worker")? as usize,
        tokens,
        latency_s,
        tpot_s: field("tpot_s")?,
        queue_wait_s: field("queue_wait_s")?,
    }))
}

/// Extract one sample value from a Prometheus exposition document.
/// Matches `name 1.5` and `name{labels} 1.5` lines.
pub fn prom_value(text: &str, name: &str) -> Option<f64> {
    for line in text.lines() {
        if line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix(name) {
            if !(rest.starts_with(' ') || rest.starts_with('{')) {
                continue; // longer metric name sharing the prefix
            }
            if let Some(tok) = rest.rsplit(' ').next() {
                if let Ok(x) = tok.trim().parse::<f64>() {
                    return Some(x);
                }
            }
        }
    }
    None
}

/// Combine client-side measurements with the gateway's `/metrics` and
/// `/v0/workers` into the simulator's [`Report`] shape.  Server-side
/// counters are *diffed* against the pre-run snapshot, so the report
/// covers this run only, not the gateway's lifetime.  Returns
/// `(policy_name, report)`.
pub fn fetch_report(authority: &str, res: &LoadGenResult) -> Result<(String, Report)> {
    let workers = http_call(authority, "GET", "/v0/workers", None)?;
    let wj = Json::parse(workers.body_str()?)
        .map_err(|e| anyhow!("bad /v0/workers json: {e}"))?;
    let policy = wj
        .get("policy")
        .and_then(Json::as_str)
        .unwrap_or("unknown")
        .to_string();

    let before = |name: &str| prom_value(&res.metrics_before, name).unwrap_or(0.0);
    let after = |name: &str| prom_value(&res.metrics_after, name).unwrap_or(0.0);
    let steps_b = before("bfio_steps_total");
    let steps_a = after("bfio_steps_total");
    let steps_run = (steps_a - steps_b).max(0.0);
    let steps = steps_run as u64;
    let energy_j =
        (after("bfio_energy_joules") - before("bfio_energy_joules")).max(0.0);
    // avg = imb_sum/steps, so the run's average recovers exactly from
    // the two (average, steps) pairs.
    let imb_sum_run =
        after("bfio_avg_imbalance") * steps_a - before("bfio_avg_imbalance") * steps_b;
    let avg_imbalance = if steps_run > 0.0 {
        (imb_sum_run / steps_run).max(0.0)
    } else {
        0.0
    };

    let report = Report {
        steps,
        avg_imbalance,
        mean_idle_fraction: 0.0, // not exposed per-step over HTTP
        throughput_tps: if res.wall_s > 0.0 {
            res.tokens as f64 / res.wall_s
        } else {
            0.0
        },
        tpot_s: stats::mean(&res.tpots_s),
        tpot_p99_s: if res.tpots_s.is_empty() {
            0.0
        } else {
            stats::percentile(&res.tpots_s, 99.0)
        },
        // Ratio gauge, not a diffable counter: this is the gateway's
        // lifetime goodput (exact for a fresh gateway, the CI case).
        slo_goodput: after("bfio_slo_goodput_ratio"),
        mean_queue_wait_s: stats::mean(&res.queue_waits_s),
        completed: res.completed as u64,
        completions: Vec::new(),
        total_tokens: res.tokens as f64,
        wall_time_s: res.wall_s,
        sync_energy_j: 0.0,
        total_energy_j: energy_j,
        energy_useful_j: (after("bfio_energy_useful_joules")
            - before("bfio_energy_useful_joules"))
        .max(0.0),
        energy_idle_j: (after("bfio_energy_idle_joules")
            - before("bfio_energy_idle_joules"))
        .max(0.0),
        energy_correction_j: (after("bfio_energy_correction_joules")
            - before("bfio_energy_correction_joules"))
        .max(0.0),
        eta_sum: 0.0,
        total_workload: 0.0,
        imb_tot: 0.0,
        obs: Default::default(),
        series: None,
    };
    Ok((policy, report))
}

/// Human summary of one run (client-side view + per-worker spread).
pub fn print_summary(cfg: &LoadGenConfig, res: &LoadGenResult) {
    println!(
        "loadgen: {} ok, {} shed, {} errors over {} clients in {:.3}s  \
         ({:.1} req/s, {:.1} tok/s, {} server retries)",
        res.completed,
        res.sheds,
        res.errors,
        cfg.concurrency,
        res.wall_s,
        res.completed as f64 / res.wall_s.max(1e-9),
        res.tokens as f64 / res.wall_s.max(1e-9),
        res.retries,
    );
    if !res.latencies_s.is_empty() {
        println!(
            "  wall latency: mean {:.4}s  p99 {:.4}s   server tpot: mean {:.4}s",
            stats::mean(&res.latencies_s),
            stats::percentile(&res.latencies_s, 99.0),
            stats::mean(&res.tpots_s),
        );
    }
    let spread: Vec<String> = res
        .per_worker
        .iter()
        .map(|(w, n)| format!("{w}:{n}"))
        .collect();
    println!("  per-worker completions: {}", spread.join(" "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prom_value_parses_labelled_and_bare() {
        let text = "\
# HELP bfio_imbalance x
# TYPE bfio_imbalance gauge
bfio_imbalance 12.5
bfio_requests_total{policy=\"jsq\"} 7
bfio_imbalance_extra 99
";
        assert_eq!(prom_value(text, "bfio_imbalance"), Some(12.5));
        assert_eq!(prom_value(text, "bfio_requests_total"), Some(7.0));
        assert_eq!(prom_value(text, "bfio_missing"), None);
        // prefix must not match the longer name
        assert_eq!(prom_value(text, "bfio_imbalance_extra"), Some(99.0));
    }

    #[test]
    fn zero_requests_rejected() {
        let cfg = LoadGenConfig { requests: 0, ..LoadGenConfig::default() };
        assert!(run(&cfg).is_err());
    }
}
