//! The epoll reactor: the gateway's non-blocking intake loop.
//!
//! One thread owns every socket.  Readiness comes from the raw-syscall
//! [`super::epoll`] binding (level-triggered); each connection is a
//! small state machine: bytes accumulate in a read buffer, an
//! incremental HTTP/1.1 parser lifts out complete requests (bounded
//! head and body, keep-alive, pipelining), responses queue on a bounded
//! write buffer and drain as the socket allows.  Backpressure is
//! connection-level: a client that stops reading stops being read
//! (paused `EPOLLIN`), and a *streaming* client that stalls past the
//! write cap is disconnected rather than buffered without bound.
//!
//! Completions never block the loop.  Streaming backends get a
//! [`StreamSink`] and push [`StreamEvent`]s into the reactor's inbox
//! (eventfd wakeup); per-step token deltas are framed as SSE on the
//! fly.  Non-streaming backends run on a small blocking executor pool
//! whose results come back through the same inbox.  Admission beyond
//! the in-flight watermark is shed immediately with 429 +
//! `Retry-After`; shutdown stops accepting, flushes in-flight
//! responses under the drain deadline, then closes.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::backend::{Completion, CompletionRequest, StreamConsumer, StreamEvent, StreamSink};
use super::epoll::{
    EpollEvent, Poller, Waker, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP,
};
use super::http::{parse_head, response_bytes, sse_head_bytes, HttpRequest, ParsedHead};
use super::{
    complete_with_retries, completion_json, error_body, parse_completion, route, sse_chunk,
    sse_delta_text, sse_final, sse_full_body, GatewayConfig, Shared, MAX_RETRIES,
};

/// Poller token of the accept socket; connections count up from 2
/// (`u64::MAX` is the poller's internal waker).
const LISTENER_TOKEN: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Retry-After attached to every shed (429 and 503 alike).
const RETRY_AFTER: [(&str, &str); 1] = [("Retry-After", "1")];

/// Messages from backend threads into the reactor loop.
enum Note {
    /// A [`StreamEvent`] from a streaming backend's sink.
    Stream { conn: u64, seq: u64, ev: StreamEvent },
    /// A finished blocking completion from the executor pool.
    Exec {
        conn: u64,
        seq: u64,
        id: u64,
        prompt_n: f64,
        sse: bool,
        wall_s: f64,
        outcome: std::result::Result<Completion, String>,
    },
}

/// Lock-free enough for the purpose: producers append under a mutex and
/// kick the eventfd; the reactor swaps the vector empty each tick.
struct Inbox {
    q: Mutex<Vec<Note>>,
    waker: Waker,
}

impl Inbox {
    fn push(&self, n: Note) {
        if let Ok(mut q) = self.q.lock() {
            q.push(n);
        }
        self.waker.wake();
    }

    fn take(&self) -> Vec<Note> {
        self.q
            .lock()
            .map(|mut q| std::mem::take(&mut *q))
            .unwrap_or_default()
    }
}

impl StreamConsumer for Inbox {
    fn event(&self, conn: u64, seq: u64, ev: StreamEvent) {
        self.push(Note::Stream { conn, seq, ev });
    }
}

/// A completion handed to the blocking executor pool (backends without
/// streaming support: PJRT, replay-dash).
struct ExecJob {
    conn: u64,
    seq: u64,
    prompt_tokens: Vec<i32>,
    max_tokens: u32,
    sse: bool,
}

/// The in-flight request of a connection (strictly one at a time —
/// pipelined responses must go out in order).
struct Active {
    seq: u64,
    kind: Kind,
}

enum Kind {
    /// Waiting on the executor pool.
    Exec,
    /// Streaming natively from the backend scheduler.
    Native {
        id: u64,
        prompt_tokens: Vec<i32>,
        max_tokens: u32,
        prompt_n: f64,
        t0: Instant,
        /// SSE requested; false = plain JSON assembled from `Done`.
        sse: bool,
        /// Tokens already framed as SSE deltas.
        emitted: u64,
        attempts: u32,
        /// The SSE response head is on the wire — no more retries, and
        /// the connection must close at stream end (no Content-Length).
        head_sent: bool,
    },
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    /// Unparsed request bytes.
    buf: Vec<u8>,
    /// Bytes of `buf` already scanned for the head terminator.
    scan: usize,
    /// Parsed head awaiting its body.
    head: Option<ParsedHead>,
    /// Complete requests not yet dispatched (pipelining).
    pending: VecDeque<HttpRequest>,
    /// Write queue (`out[out_pos..]` is unsent).
    out: Vec<u8>,
    out_pos: usize,
    /// Current epoll interest mask.
    interest: u32,
    active: Option<Active>,
    /// An SSE response is being written incrementally.
    streaming: bool,
    /// Keep-alive of the request currently being answered.
    keep_alive: bool,
    /// Close once the write queue drains.
    closing: bool,
    /// Reads paused by write backpressure.
    paused: bool,
    /// Client closed its write half (or a parse error poisoned the
    /// stream) — serve what is queued, read no further.
    read_closed: bool,
    /// Error response to emit once earlier pipelined responses drain,
    /// keeping responses in request order.
    deferred: Option<(u16, String)>,
    last_activity: Instant,
    /// Set while an incomplete request sits in the buffer (read
    /// deadline / slowloris defense).
    partial_since: Option<Instant>,
    /// Next request sequence number on this connection.
    seq: u64,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            buf: Vec::new(),
            scan: 0,
            head: None,
            pending: VecDeque::new(),
            out: Vec::new(),
            out_pos: 0,
            interest: EPOLLIN | EPOLLRDHUP,
            active: None,
            streaming: false,
            keep_alive: true,
            closing: false,
            paused: false,
            read_closed: false,
            deferred: None,
            last_activity: Instant::now(),
            partial_since: None,
            seq: 0,
        }
    }

    fn unsent(&self) -> usize {
        self.out.len() - self.out_pos
    }

    fn idle(&self) -> bool {
        self.active.is_none()
            && self.pending.is_empty()
            && self.deferred.is_none()
            && self.unsent() == 0
    }
}

fn conn_queue(c: &mut Conn, bytes: &[u8]) {
    if c.out_pos > 0 {
        c.out.drain(..c.out_pos);
        c.out_pos = 0;
    }
    c.out.extend_from_slice(bytes);
}

/// Write as much of the queue as the socket takes right now.
fn conn_flush(c: &mut Conn) -> io::Result<()> {
    while c.out_pos < c.out.len() {
        match c.stream.write(&c.out[c.out_pos..]) {
            Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
            Ok(n) => {
                c.out_pos += n;
                c.last_activity = Instant::now();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    if c.out_pos >= c.out.len() {
        c.out.clear();
        c.out_pos = 0;
    }
    Ok(())
}

/// Find the `\r\n\r\n` head terminator, resuming at `scanned` (bytes
/// covered by previous searches; the window backs up 3 bytes for a
/// terminator split across reads).
fn find_blank_line(buf: &[u8], scanned: usize) -> Option<usize> {
    let start = scanned.saturating_sub(3);
    buf.windows(4)
        .skip(start)
        .position(|w| w == b"\r\n\r\n")
        .map(|p| p + start)
}

struct Reactor {
    cfg: GatewayConfig,
    shared: Arc<Shared>,
    poller: Poller,
    inbox: Arc<Inbox>,
    exec_tx: Sender<ExecJob>,
    listener: Option<TcpListener>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    /// Completions in flight (admission watermark).
    inflight: usize,
    draining: bool,
    drain_deadline: Option<Instant>,
    model: String,
}

/// Spawn the reactor thread (plus its blocking executor pool) and
/// return the join handle and a waker for shutdown.
pub(super) fn spawn(
    cfg: GatewayConfig,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    shared: Arc<Shared>,
) -> Result<(JoinHandle<()>, Waker)> {
    listener
        .set_nonblocking(true)
        .context("set listener nonblocking")?;
    let poller = Poller::new().context("epoll_create1")?;
    poller
        .add(listener.as_raw_fd(), LISTENER_TOKEN, EPOLLIN)
        .context("register listener")?;
    let waker = poller.waker();
    let inbox = Arc::new(Inbox {
        q: Mutex::new(Vec::new()),
        waker: poller.waker(),
    });

    // Blocking executor pool for backends without streaming support.
    // Workers exit when the reactor drops the job sender; they are not
    // joined — a worker stuck in a slow backend call must not hold up
    // the drain deadline.
    let (exec_tx, exec_rx) = channel::<ExecJob>();
    let exec_rx = Arc::new(Mutex::new(exec_rx));
    for _ in 0..cfg.threads.max(1) {
        let rx = Arc::clone(&exec_rx);
        let shared = Arc::clone(&shared);
        let inbox = Arc::clone(&inbox);
        std::thread::spawn(move || loop {
            let job = match rx.lock() {
                Ok(guard) => guard.recv(),
                Err(_) => break,
            };
            let Ok(job) = job else { break };
            let t0 = Instant::now();
            let prompt_n = job.prompt_tokens.len() as f64;
            let (id, outcome) =
                complete_with_retries(&shared, &job.prompt_tokens, job.max_tokens);
            inbox.push(Note::Exec {
                conn: job.conn,
                seq: job.seq,
                id,
                prompt_n,
                sse: job.sse,
                wall_s: t0.elapsed().as_secs_f64(),
                outcome,
            });
        });
    }

    let model = shared.backend.name();
    let reactor = Reactor {
        cfg,
        shared,
        poller,
        inbox,
        exec_tx,
        listener: Some(listener),
        conns: HashMap::new(),
        next_token: FIRST_CONN_TOKEN,
        inflight: 0,
        draining: false,
        drain_deadline: None,
        model,
    };
    let handle = std::thread::spawn(move || reactor.run(stop));
    Ok((handle, waker))
}

impl Reactor {
    fn run(mut self, stop: Arc<AtomicBool>) {
        let mut events = [EpollEvent::zeroed(); 256];
        loop {
            let n = match self.poller.wait(&mut events, 100) {
                Ok(n) => n,
                Err(_) => break,
            };
            if stop.load(Ordering::SeqCst) && !self.draining {
                self.begin_drain();
            }
            for note in self.inbox.take() {
                self.handle_note(note);
            }
            for ev in events.iter().take(n) {
                let token = ev.data;
                let mask = ev.events;
                if token == LISTENER_TOKEN {
                    self.accept_ready();
                    continue;
                }
                if mask & (EPOLLERR | EPOLLHUP) != 0 {
                    self.remove_conn(token);
                    continue;
                }
                if mask & (EPOLLIN | EPOLLRDHUP) != 0 {
                    self.on_readable(token);
                }
                if mask & EPOLLOUT != 0 {
                    self.flush_and_update(token);
                }
            }
            self.sweep_timers();
            if self.draining {
                let expired = self
                    .drain_deadline
                    .map(|d| Instant::now() >= d)
                    .unwrap_or(true);
                if self.conns.is_empty() || expired {
                    break;
                }
            }
        }
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            self.remove_conn(t);
        }
        // Dropping `exec_tx` lets idle executor workers exit.
    }

    fn begin_drain(&mut self) {
        self.draining = true;
        self.drain_deadline = Some(Instant::now() + self.cfg.drain);
        if let Some(l) = self.listener.take() {
            let _ = self.poller.delete(l.as_raw_fd());
            // Dropping closes the socket: new connections are refused
            // at the kernel while in-flight responses drain.
        }
    }

    fn accept_ready(&mut self) {
        loop {
            let Some(listener) = self.listener.as_ref() else {
                return;
            };
            match listener.accept() {
                Ok((stream, _)) => {
                    if self.conns.len() >= self.cfg.max_conns {
                        // Best-effort shed: the response may not fit in
                        // the socket buffer of a hostile peer, but we
                        // will not block or track the connection.
                        self.shared.sheds.fetch_add(1, Ordering::Relaxed);
                        let mut s = stream;
                        let _ = s.set_nonblocking(true);
                        let _ = s.write(&response_bytes(
                            503,
                            "application/json",
                            &RETRY_AFTER,
                            &error_body("connection limit reached"),
                            false,
                        ));
                        continue;
                    }
                    stream.set_nonblocking(true).ok();
                    stream.set_nodelay(true).ok();
                    let token = self.next_token;
                    self.next_token += 1;
                    if self
                        .poller
                        .add(stream.as_raw_fd(), token, EPOLLIN | EPOLLRDHUP)
                        .is_err()
                    {
                        continue;
                    }
                    self.shared.conns.fetch_add(1, Ordering::Relaxed);
                    self.conns.insert(token, Conn::new(stream));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    fn remove_conn(&mut self, token: u64) {
        if let Some(c) = self.conns.remove(&token) {
            let _ = self.poller.delete(c.stream.as_raw_fd());
            self.shared.conns.fetch_sub(1, Ordering::Relaxed);
            // An active request keeps running backend-side; its
            // terminal note decrements `inflight` when it arrives and
            // finds the connection gone.
        }
    }

    fn on_readable(&mut self, token: u64) {
        let mut kill = false;
        match self.conns.get_mut(&token) {
            None => return,
            Some(c) => {
                if !c.read_closed && !c.paused {
                    let mut tmp = [0u8; 16 * 1024];
                    loop {
                        match c.stream.read(&mut tmp) {
                            Ok(0) => {
                                c.read_closed = true;
                                if c.idle() && c.buf.is_empty() && c.head.is_none() {
                                    kill = true;
                                }
                                break;
                            }
                            Ok(n) => {
                                c.buf.extend_from_slice(&tmp[..n]);
                                c.last_activity = Instant::now();
                                if c.partial_since.is_none() {
                                    c.partial_since = Some(Instant::now());
                                }
                                // Hard cap on runaway buffering: one
                                // head plus one body, no matter what.
                                if c.buf.len()
                                    > self.cfg.max_header_bytes + self.cfg.max_body_bytes
                                {
                                    break;
                                }
                            }
                            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                            Err(_) => {
                                kill = true;
                                break;
                            }
                        }
                    }
                }
            }
        }
        if kill {
            self.remove_conn(token);
            return;
        }
        self.process_conn(token);
    }

    /// Parse buffered bytes into requests, dispatch up to one active
    /// completion (answering everything else synchronously), then
    /// flush.  Safe to call whenever a connection's state may have
    /// advanced.
    fn process_conn(&mut self, token: u64) {
        if let Some(c) = self.conns.get_mut(&token) {
            // --- incremental parse ---
            loop {
                if c.closing || c.deferred.is_some() {
                    break;
                }
                if c.pending.len() >= self.cfg.pipeline_cap {
                    break;
                }
                if let Some(h) = c.head.take() {
                    if c.buf.len() < h.content_length {
                        c.head = Some(h);
                        break;
                    }
                    let body: Vec<u8> = c.buf.drain(..h.content_length).collect();
                    self.shared.http_requests.fetch_add(1, Ordering::Relaxed);
                    c.pending.push_back(HttpRequest {
                        method: h.method,
                        target: h.target,
                        headers: h.headers,
                        body,
                    });
                    c.partial_since = if c.buf.is_empty() {
                        None
                    } else {
                        Some(Instant::now())
                    };
                    continue;
                }
                let Some(p) = find_blank_line(&c.buf, c.scan) else {
                    if c.buf.len() > self.cfg.max_header_bytes {
                        self.shared.http_requests.fetch_add(1, Ordering::Relaxed);
                        self.shared.bad_requests.fetch_add(1, Ordering::Relaxed);
                        c.read_closed = true;
                        c.deferred = Some((431, "request head too large".to_string()));
                    }
                    c.scan = c.buf.len();
                    break;
                };
                match parse_head(&c.buf[..p]) {
                    Ok(h) => {
                        if h.content_length > self.cfg.max_body_bytes {
                            self.shared.http_requests.fetch_add(1, Ordering::Relaxed);
                            self.shared.bad_requests.fetch_add(1, Ordering::Relaxed);
                            c.read_closed = true;
                            c.deferred = Some((
                                413,
                                format!(
                                    "declared body of {} bytes exceeds the limit",
                                    h.content_length
                                ),
                            ));
                            break;
                        }
                        c.buf.drain(..p + 4);
                        c.scan = 0;
                        c.head = Some(h);
                    }
                    Err(e) => {
                        // The framing is untrustworthy from here on:
                        // poison the read side, answer 400 once earlier
                        // responses drain, then close.
                        self.shared.http_requests.fetch_add(1, Ordering::Relaxed);
                        self.shared.bad_requests.fetch_add(1, Ordering::Relaxed);
                        c.read_closed = true;
                        c.deferred = Some((400, format!("{e:#}")));
                        break;
                    }
                }
            }

            // --- dispatch (strictly in order, one active at a time) ---
            loop {
                if c.active.is_some() || c.streaming || c.closing {
                    break;
                }
                let Some(req) = c.pending.pop_front() else {
                    if let Some((status, msg)) = c.deferred.take() {
                        conn_queue(
                            c,
                            &response_bytes(
                                status,
                                "application/json",
                                &[],
                                &error_body(&msg),
                                false,
                            ),
                        );
                        c.closing = true;
                    }
                    break;
                };
                c.keep_alive = req.keep_alive();
                let ka = c.keep_alive;
                if !(req.method == "POST" && req.path() == "/v1/completions") {
                    match route(&req, &self.shared) {
                        Ok((status, ctype, body)) => {
                            let extra: &[(&str, &str)] =
                                if status == 503 { &RETRY_AFTER } else { &[] };
                            conn_queue(c, &response_bytes(status, ctype, extra, &body, ka));
                        }
                        Err(e) => {
                            conn_queue(
                                c,
                                &response_bytes(
                                    500,
                                    "application/json",
                                    &[],
                                    &error_body(&format!("{e:#}")),
                                    ka,
                                ),
                            );
                        }
                    }
                    if !ka {
                        c.closing = true;
                    }
                    continue;
                }
                let params = match parse_completion(&req, &self.shared) {
                    Ok(p) => p,
                    Err((status, ctype, body)) => {
                        conn_queue(c, &response_bytes(status, ctype, &[], &body, ka));
                        if !ka {
                            c.closing = true;
                        }
                        continue;
                    }
                };
                if self.draining {
                    self.shared.sheds.fetch_add(1, Ordering::Relaxed);
                    conn_queue(
                        c,
                        &response_bytes(
                            503,
                            "application/json",
                            &RETRY_AFTER,
                            &error_body("gateway is draining"),
                            false,
                        ),
                    );
                    c.closing = true;
                    continue;
                }
                if self.inflight >= self.cfg.max_inflight {
                    // Admission watermark: shed before touching the
                    // backend so overload cost stays O(parse).
                    self.shared.sheds.fetch_add(1, Ordering::Relaxed);
                    conn_queue(
                        c,
                        &response_bytes(
                            429,
                            "application/json",
                            &RETRY_AFTER,
                            &error_body("admission watermark reached, retry later"),
                            ka,
                        ),
                    );
                    if !ka {
                        c.closing = true;
                    }
                    continue;
                }
                let seq = c.seq;
                c.seq += 1;
                if params.stream {
                    self.shared.streams.fetch_add(1, Ordering::Relaxed);
                }
                self.inflight += 1;
                if self.shared.backend.supports_streaming() {
                    let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
                    let prompt_n = params.prompt_tokens.len() as f64;
                    let sink = StreamSink::new(
                        token,
                        seq,
                        params.stream,
                        Arc::clone(&self.inbox) as Arc<dyn StreamConsumer>,
                    );
                    c.active = Some(Active {
                        seq,
                        kind: Kind::Native {
                            id,
                            prompt_tokens: params.prompt_tokens.clone(),
                            max_tokens: params.max_tokens,
                            prompt_n,
                            t0: Instant::now(),
                            sse: params.stream,
                            emitted: 0,
                            attempts: 0,
                            head_sent: false,
                        },
                    });
                    // A submit error drops the sink, which fires a
                    // Failed note — the single event path handles it.
                    let _ = self.shared.backend.submit_stream(
                        CompletionRequest {
                            id,
                            prompt_tokens: params.prompt_tokens,
                            max_tokens: params.max_tokens,
                        },
                        sink,
                    );
                } else {
                    c.active = Some(Active { seq, kind: Kind::Exec });
                    let _ = self.exec_tx.send(ExecJob {
                        conn: token,
                        seq,
                        prompt_tokens: params.prompt_tokens,
                        max_tokens: params.max_tokens,
                        sse: params.stream,
                    });
                }
                break;
            }
        }
        self.flush_and_update(token);
    }

    /// Flush the write queue, apply backpressure, refresh epoll
    /// interest, and close fully-drained closing connections.
    fn flush_and_update(&mut self, token: u64) {
        let mut kill = false;
        if let Some(c) = self.conns.get_mut(&token) {
            if conn_flush(c).is_err() {
                kill = true;
            }
            if !kill {
                let buffered = c.unsent();
                if buffered > self.cfg.write_buf_cap {
                    if c.streaming {
                        // A stalled SSE consumer would otherwise grow
                        // the buffer one delta per barrier step forever.
                        kill = true;
                    } else {
                        c.paused = true;
                    }
                } else if c.paused && buffered <= self.cfg.write_buf_cap / 2 {
                    c.paused = false;
                }
            }
            if !kill
                && c.closing
                && c.unsent() == 0
                && c.active.is_none()
            {
                kill = true;
            }
            // A half-closed client with nothing left to serve gets
            // reaped now rather than at the idle timeout.
            if !kill && c.read_closed && c.idle() {
                kill = true;
            }
            if !kill {
                let want_read = !c.closing
                    && !c.paused
                    && !c.read_closed
                    && c.pending.len() < self.cfg.pipeline_cap;
                let want_write = c.unsent() > 0;
                let mut interest = EPOLLRDHUP;
                if want_read {
                    interest |= EPOLLIN;
                }
                if want_write {
                    interest |= EPOLLOUT;
                }
                if interest != c.interest {
                    c.interest = interest;
                    let _ = self.poller.modify(c.stream.as_raw_fd(), token, interest);
                }
            }
        }
        if kill {
            self.remove_conn(token);
        }
    }

    fn handle_note(&mut self, note: Note) {
        match note {
            Note::Exec {
                conn,
                seq,
                id,
                prompt_n,
                sse,
                wall_s,
                outcome,
            } => {
                self.inflight = self.inflight.saturating_sub(1);
                if let Some(c) = self.conns.get_mut(&conn) {
                    if c.active.as_ref().map(|a| a.seq) == Some(seq) {
                        c.active = None;
                        let ka = c.keep_alive;
                        match outcome {
                            Ok(done) => {
                                if sse {
                                    conn_queue(
                                        c,
                                        &response_bytes(
                                            200,
                                            "text/event-stream",
                                            &[],
                                            &sse_full_body(
                                                id,
                                                &self.model,
                                                prompt_n,
                                                &done,
                                                wall_s,
                                            ),
                                            ka,
                                        ),
                                    );
                                } else {
                                    conn_queue(
                                        c,
                                        &response_bytes(
                                            200,
                                            "application/json",
                                            &[],
                                            &completion_json(
                                                id,
                                                &self.model,
                                                prompt_n,
                                                &done,
                                                wall_s,
                                            ),
                                            ka,
                                        ),
                                    );
                                }
                            }
                            Err(last_err) => {
                                conn_queue(
                                    c,
                                    &response_bytes(
                                        503,
                                        "application/json",
                                        &RETRY_AFTER,
                                        &error_body(&format!(
                                            "backend unavailable after {MAX_RETRIES} \
                                             retries: {last_err}"
                                        )),
                                        ka,
                                    ),
                                );
                            }
                        }
                        if !ka {
                            c.closing = true;
                        }
                    }
                }
                self.process_conn(conn);
            }
            Note::Stream { conn, seq, ev } => self.handle_stream_event(conn, seq, ev),
        }
    }

    fn handle_stream_event(&mut self, conn: u64, seq: u64, ev: StreamEvent) {
        match ev {
            StreamEvent::Delta { tokens, .. } => {
                if let Some(c) = self.conns.get_mut(&conn) {
                    let mut push: Vec<u8> = Vec::new();
                    let mut became_streaming = false;
                    if let Some(a) = c.active.as_mut() {
                        if a.seq == seq {
                            if let Kind::Native {
                                id,
                                sse,
                                emitted,
                                head_sent,
                                ..
                            } = &mut a.kind
                            {
                                if *sse {
                                    if !*head_sent {
                                        push.extend_from_slice(&sse_head_bytes());
                                        *head_sent = true;
                                        became_streaming = true;
                                    }
                                    for t in &tokens {
                                        push.extend_from_slice(
                                            sse_chunk(
                                                *id,
                                                &self.model,
                                                &sse_delta_text(*emitted, *t),
                                            )
                                            .as_bytes(),
                                        );
                                        *emitted += 1;
                                    }
                                }
                            }
                        }
                    }
                    if became_streaming {
                        c.streaming = true;
                    }
                    if !push.is_empty() {
                        conn_queue(c, &push);
                    }
                }
                self.flush_and_update(conn);
            }
            StreamEvent::Done(done) => {
                self.inflight = self.inflight.saturating_sub(1);
                if let Some(c) = self.conns.get_mut(&conn) {
                    let mut push: Vec<u8> = Vec::new();
                    let mut matched = false;
                    let mut close_stream = false;
                    if let Some(a) = c.active.as_mut() {
                        if a.seq == seq {
                            matched = true;
                            if let Kind::Native {
                                id,
                                prompt_n,
                                t0,
                                sse,
                                emitted,
                                head_sent,
                                ..
                            } = &mut a.kind
                            {
                                let wall_s = t0.elapsed().as_secs_f64();
                                if *sse {
                                    if !*head_sent {
                                        push.extend_from_slice(&sse_head_bytes());
                                        *head_sent = true;
                                    }
                                    // Deltas the periodic emitter had
                                    // not surfaced yet (the final step
                                    // finishes before the next barrier
                                    // publishes progress).
                                    while (*emitted as usize) < done.tokens.len() {
                                        let j = *emitted;
                                        let t = done.tokens[j as usize];
                                        push.extend_from_slice(
                                            sse_chunk(
                                                *id,
                                                &self.model,
                                                &sse_delta_text(j, t),
                                            )
                                            .as_bytes(),
                                        );
                                        *emitted += 1;
                                    }
                                    push.extend_from_slice(
                                        sse_final(*id, &self.model, *prompt_n, &done, wall_s)
                                            .as_bytes(),
                                    );
                                    close_stream = true;
                                } else {
                                    push.extend_from_slice(&response_bytes(
                                        200,
                                        "application/json",
                                        &[],
                                        &completion_json(
                                            *id,
                                            &self.model,
                                            *prompt_n,
                                            &done,
                                            wall_s,
                                        ),
                                        c.keep_alive,
                                    ));
                                }
                            }
                        }
                    }
                    if matched {
                        c.active = None;
                        if close_stream {
                            // SSE has no Content-Length: end-of-stream
                            // is end-of-connection.
                            c.streaming = false;
                            c.closing = true;
                        } else if !c.keep_alive {
                            c.closing = true;
                        }
                        conn_queue(c, &push);
                    }
                }
                self.process_conn(conn);
            }
            StreamEvent::Failed(err) => {
                let mut resubmit: Option<(u64, Vec<i32>, u32, bool)> = None;
                let mut kill = false;
                let mut terminal = true;
                if let Some(c) = self.conns.get_mut(&conn) {
                    let mut push: Vec<u8> = Vec::new();
                    let mut matched = false;
                    if let Some(a) = c.active.as_mut() {
                        if a.seq == seq {
                            matched = true;
                            if let Kind::Native {
                                id,
                                prompt_tokens,
                                max_tokens,
                                sse,
                                emitted,
                                attempts,
                                head_sent,
                                ..
                            } = &mut a.kind
                            {
                                if *attempts < MAX_RETRIES
                                    && *emitted == 0
                                    && !*head_sent
                                    && !self.draining
                                {
                                    // Transparent retry under a fresh id
                                    // (no backoff — the reactor thread
                                    // must not sleep; the fault ledger
                                    // already resolved the old id).
                                    *attempts += 1;
                                    self.shared.retries.fetch_add(1, Ordering::Relaxed);
                                    let new_id =
                                        self.shared.next_id.fetch_add(1, Ordering::Relaxed);
                                    *id = new_id;
                                    resubmit = Some((
                                        new_id,
                                        prompt_tokens.clone(),
                                        *max_tokens,
                                        *sse,
                                    ));
                                    terminal = false;
                                } else if *head_sent {
                                    // Mid-stream failure with the 200
                                    // head on the wire: truncate (no
                                    // [DONE]) so the client sees the
                                    // stream die rather than a forged
                                    // success.
                                    self.shared.sheds.fetch_add(1, Ordering::Relaxed);
                                    kill = true;
                                } else {
                                    self.shared.sheds.fetch_add(1, Ordering::Relaxed);
                                    push.extend_from_slice(&response_bytes(
                                        503,
                                        "application/json",
                                        &RETRY_AFTER,
                                        &error_body(&format!(
                                            "backend unavailable after {MAX_RETRIES} \
                                             retries: {err}"
                                        )),
                                        c.keep_alive,
                                    ));
                                }
                            }
                        }
                    }
                    if matched && terminal && !kill {
                        c.active = None;
                        conn_queue(c, &push);
                        if !c.keep_alive {
                            c.closing = true;
                        }
                    }
                }
                if terminal {
                    self.inflight = self.inflight.saturating_sub(1);
                }
                if let Some((new_id, prompt_tokens, max_tokens, sse)) = resubmit {
                    let sink = StreamSink::new(
                        conn,
                        seq,
                        sse,
                        Arc::clone(&self.inbox) as Arc<dyn StreamConsumer>,
                    );
                    let _ = self.shared.backend.submit_stream(
                        CompletionRequest {
                            id: new_id,
                            prompt_tokens,
                            max_tokens,
                        },
                        sink,
                    );
                }
                if kill {
                    self.remove_conn(conn);
                } else {
                    self.process_conn(conn);
                }
            }
        }
    }

    fn sweep_timers(&mut self) {
        let now = Instant::now();
        let mut expired: Vec<u64> = Vec::new();
        let mut idle: Vec<u64> = Vec::new();
        for (t, c) in &self.conns {
            if c.closing || c.deferred.is_some() {
                continue;
            }
            if let Some(since) = c.partial_since {
                if now.duration_since(since) > self.cfg.read_deadline {
                    expired.push(*t);
                }
            } else if c.idle() && now.duration_since(c.last_activity) > self.cfg.idle_timeout {
                idle.push(*t);
            }
        }
        for t in expired {
            if let Some(c) = self.conns.get_mut(&t) {
                c.read_closed = true;
                c.head = None;
                c.deferred =
                    Some((408, "request not completed within the read deadline".to_string()));
            }
            self.process_conn(t);
        }
        for t in idle {
            self.remove_conn(t);
        }
        if self.draining {
            // Keep-alive connections with nothing in flight have no
            // reason to outlive the drain.
            let parked: Vec<u64> = self
                .conns
                .iter()
                .filter(|(_, c)| c.idle() && !c.streaming)
                .map(|(t, _)| *t)
                .collect();
            for t in parked {
                self.remove_conn(t);
            }
        }
    }
}
