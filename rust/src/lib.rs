//! # bfio-serve — a universal load-balancing principle for LLM serving
//!
//! Reproduction of *"A Universal Load Balancing Principle and Its
//! Application to Large Language Model Serving"* (CS.DC 2026): the **BF-IO**
//! (Balance Future with Integer Optimization) routing principle for
//! barrier-synchronized, data-parallel LLM decode with sticky (KV-bound,
//! non-migratable) request assignments.
//!
//! The crate is organized as the Layer-3 coordinator of a three-layer
//! Rust + JAX + Pallas stack (see `DESIGN.md`):
//!
//! * [`workload`] — request/trace substrate: workload profiles
//!   `W_i = (s_i, s_i + δ_1, …)`, LongBench/BurstGPT-like samplers,
//!   adversarial and overloaded arrival instances, drift models.
//! * [`sim`] — discrete-event decode simulator with the paper's time model
//!   `Δt = C + t_ℓ · max_g L_g(k)` and per-step barrier synchronization.
//! * [`policies`] — FCFS (Algorithm 2), JSQ, Round-Robin, Power-of-d,
//!   Min-Min, Max-Min, OLB, Throttled, and BF-IO(H) with its integer
//!   optimization solver (exact branch-and-bound + greedy/local-search).
//! * [`metrics`] — AvgImbalance, throughput, TPOT, idle time, trajectories,
//!   and Prometheus text exposition.
//! * [`gateway`] — the HTTP serving surface: an OpenAI-style
//!   `/v1/completions` endpoint, `/v0/workers` status, `/metrics`, and
//!   `/healthz` on a hand-rolled HTTP/1.1 server, decoupled from
//!   execution by a `Backend` trait (discrete-event sim in virtual time,
//!   the multi-replica fleet, or the live PJRT coordinator), plus a
//!   closed-loop load generator.
//! * [`fault`] — deterministic fault injection: seeded crash /
//!   fail-slow / recovery plans applied at round boundaries, plus the
//!   health-monitor knobs (Healthy → Suspect → Down → Recovering) the
//!   fleet's replica state machine runs on.
//! * [`fleet`] — two-level routing across R data-parallel barrier-group
//!   replicas: a tier-1 `FleetRouter` (weighted-RR, least-outstanding,
//!   power-of-d, two-level BF-IO) in front of per-replica engines with
//!   heterogeneous speeds/shapes and lifecycle churn (drain/add/remove).
//! * [`autoscale`] — the energy-aware elastic control plane over the
//!   fleet: per-round signals (outstanding work, Eq. 19 step time,
//!   completion horizon, Theorem-4 energy rates), scale policies
//!   (static / target-tracking / energy-marginal) with hysteresis, and
//!   an actuator that drains/adds/reactivates replicas live.
//! * [`obs`] — the end-to-end observability layer: request lifecycle
//!   span tracing into per-thread flight recorders (JSONL / Chrome
//!   `trace_event` export, `GET /v0/trace`), mergeable DDSketch-style
//!   quantile sketches for TTFT/TPOT/step-time/imbalance, the per-round
//!   fleet profiler, and the SLO-goodput metric.
//! * [`energy`] — the GPU power model `P(mfu)` and per-step energy
//!   integration (Section 5.2 / Appendix D of the paper).
//! * [`theory`] — closed-form theorem bounds and empirical IIR drivers.
//! * [`runtime`] — PJRT execution of the AOT-compiled TinyLM artifacts.
//! * [`coordinator`] — the online serving runtime (leader/worker threads,
//!   barrier decode loop, real model execution per worker).
//! * [`util`] — self-built substrates (PRNG + distributions, JSON, CLI,
//!   bench + property-test harnesses) — the build image has no crates.io
//!   access beyond `xla`/`anyhow`, so these are implemented from scratch.

pub mod autoscale;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod energy;
pub mod fault;
pub mod fleet;
pub mod gateway;
pub mod metrics;
pub mod obs;
pub mod policies;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod theory;
pub mod util;
pub mod workload;

pub use config::SimConfig;
pub use sim::{SimResult, Simulator};
