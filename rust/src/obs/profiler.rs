//! Per-round fleet execution profiler: wall time per round, pool
//! threads engaged, router decision time, and the per-replica straggler
//! gap, accumulated into streaming sketches and exposed as the
//! `bfio_round_*` metric family on the gateway.
//!
//! Wall-clock figures here are observability-only: they are measured
//! around the round, never fed back into virtual time, so the profiler
//! cannot perturb the deterministic parallel ≡ serial fleet results.

use super::sketch::QuantileSketch;

/// Streaming per-round profile of a fleet core (or any round-driven
/// driver).  All sketches use the default relative accuracy.
#[derive(Clone, Debug, Default)]
pub struct RoundProfiler {
    /// Rounds profiled.
    pub rounds: u64,
    /// Wall time per `run_round` call, seconds.
    pub round_wall: QuantileSketch,
    /// Wall time per router decision (`route_in`), seconds.
    pub router_wall: QuantileSketch,
    /// Per-round straggler gap: spread `max − min` of the live
    /// replicas' virtual clocks, seconds — how far the slowest replica
    /// trails the fastest at the round boundary.
    pub straggler_gap: QuantileSketch,
    /// Wall seconds of the most recent round.
    pub last_round_wall_s: f64,
    /// Straggler gap of the most recent round, seconds.
    pub last_straggler_gap_s: f64,
    /// Threads engaged by the most recent round, caller included
    /// (1 = serial execution).
    pub last_threads_engaged: usize,
    /// Σ threads engaged over all rounds (mean = sum / rounds).
    pub threads_engaged_sum: u64,
}

impl RoundProfiler {
    /// Record one completed round.
    pub fn record_round(&mut self, wall_s: f64, threads_engaged: usize, gap_s: f64) {
        self.rounds += 1;
        self.round_wall.insert(wall_s);
        self.straggler_gap.insert(gap_s);
        self.last_round_wall_s = wall_s;
        self.last_straggler_gap_s = gap_s;
        self.last_threads_engaged = threads_engaged;
        self.threads_engaged_sum += threads_engaged as u64;
    }

    /// Record one router decision's wall time.
    pub fn record_route(&mut self, wall_s: f64) {
        self.router_wall.insert(wall_s);
    }

    /// Mean pool threads engaged per round.
    pub fn mean_threads_engaged(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.threads_engaged_sum as f64 / self.rounds as f64
        }
    }

    /// Copy `src` into `self`, reusing existing sketch allocations (the
    /// fleet's in-place snapshot publish path).
    pub fn copy_from(&mut self, src: &RoundProfiler) {
        self.rounds = src.rounds;
        self.round_wall.copy_from(&src.round_wall);
        self.router_wall.copy_from(&src.router_wall);
        self.straggler_gap.copy_from(&src.straggler_gap);
        self.last_round_wall_s = src.last_round_wall_s;
        self.last_straggler_gap_s = src.last_straggler_gap_s;
        self.last_threads_engaged = src.last_threads_engaged;
        self.threads_engaged_sum = src.threads_engaged_sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_rounds_and_routes() {
        let mut p = RoundProfiler::default();
        assert_eq!(p.mean_threads_engaged(), 0.0);
        p.record_round(0.010, 3, 0.5);
        p.record_round(0.020, 1, 0.25);
        p.record_route(0.0001);
        assert_eq!(p.rounds, 2);
        assert_eq!(p.last_threads_engaged, 1);
        assert!((p.mean_threads_engaged() - 2.0).abs() < 1e-12);
        assert_eq!(p.round_wall.count(), 2);
        assert_eq!(p.straggler_gap.count(), 2);
        assert_eq!(p.router_wall.count(), 1);
        assert!((p.last_round_wall_s - 0.020).abs() < 1e-12);

        let mut q = RoundProfiler::default();
        q.copy_from(&p);
        assert_eq!(q.rounds, 2);
        assert_eq!(q.round_wall.count(), 2);
        assert!((q.mean_threads_engaged() - 2.0).abs() < 1e-12);
    }
}
