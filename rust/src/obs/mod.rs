//! End-to-end observability layer: request lifecycle tracing, streaming
//! quantile sketches, and SLO-goodput.
//!
//! The paper's argument is about *where time goes* — barrier idle
//! fractions, straggler-gated steps, Theorem-4 energy waste — so the
//! serving stack needs per-request timing signals that survive the
//! million-request scale target without storing every sample.  This
//! module provides three substrates, all allocation-bounded:
//!
//! * [`sketch::QuantileSketch`] — a DDSketch-style relative-error
//!   quantile sketch (log-γ buckets, mergeable across replicas and
//!   threads) that replaces the `Vec<f64>`-and-sort percentile path for
//!   TTFT / TPOT / step-time / imbalance.  Any quantile it reports is
//!   within a configurable relative error `α` (default
//!   [`sketch::DEFAULT_ALPHA`]) of the exact sample quantile.
//! * [`trace`] — fixed-shape request lifecycle span events
//!   (arrival → route → admit → first-token → finish/shed) carrying both
//!   the virtual (simulated) clock and a wall-clock offset, recorded
//!   into per-thread flight-recorder ring buffers ([`trace::Tracer`])
//!   with bounded memory and zero steady-state allocation, merged into a
//!   shared [`trace::SpanLog`] once per round, and exported as JSONL or
//!   Chrome `trace_event` JSON (`GET /v0/trace` on the gateway).
//! * [`profiler::RoundProfiler`] — per-round fleet execution profile
//!   (round wall time, pool threads engaged, router decision time,
//!   per-replica straggler gap) feeding the `bfio_round_*` metric
//!   family.
//! * [`attrib::GateLedger`] — per-barrier-step straggler attribution:
//!   which worker gated each step, with the step's Theorem-4
//!   `idle + correction` joules charged to it (and blamed onto the
//!   request last placed there), under an exact ≤1e-9 conservation
//!   identity against the energy accumulators.
//! * [`regret::RegretAudit`] — online routing-regret audit
//!   (`chosen_cost − best_cost` per tier-1 decision by the router's own
//!   Eq. 19 cost model); exact routers show regret ≡ 0.
//! * [`series::SeriesRing`] — bounded windowed time-series ring behind
//!   `GET /v0/series` and the self-contained `GET /v0/dash` dashboard.
//! * [`journal::Journal`] — event-sourced run journal: every
//!   externally-sourced event a run consumes (arrivals, routing
//!   decisions + per-replica decision costs, faults, health
//!   transitions, lifecycle actions) recorded into a bounded ring with
//!   compact binary + JSONL export (`--journal` on `bfio fleet` /
//!   `bfio gateway`, `GET /v0/journal`).
//! * [`replay`] — counterfactual replay over a journal: pinned mode
//!   reproduces the recorded `FleetResult` bit-exactly (`bfio replay
//!   --check`), counterfactual mode re-decides routing under
//!   `--router` / `--no-faults` / `--speeds` overrides for
//!   trajectory-level regret postmortems.
//!
//! On top of these, [`SloConfig`] + [`RequestObs`] define the
//! **SLO-goodput** metric: the fraction of completions whose TTFT and
//! TPOT both meet configurable targets, reported in `FleetResult`,
//! gateway `/metrics` (`bfio_slo_goodput_ratio`), and the bench JSONs.
//!
//! Tracing is strictly opt-in (`--trace` on the gateway): with it off,
//! every [`trace::Tracer`] is the no-op disabled instance, nothing is
//! recorded, and no per-request heap allocation is added to the hot
//! path.  The sketches and the round profiler are always on — they are
//! O(1) amortized per sample with hard memory bounds, matching the
//! engine's zero-steady-state-allocation ethos.

pub mod attrib;
pub mod journal;
pub mod profiler;
pub mod regret;
pub mod replay;
pub mod series;
pub mod sketch;
pub mod trace;

pub use attrib::GateLedger;
pub use journal::{Journal, JournalConfig, JournalEvent, JournalRing, ResultSummary};
pub use profiler::RoundProfiler;
pub use regret::RegretAudit;
pub use replay::{replay_journal, PinnedRouter, ReplayOptions, ReplayOutcome};
pub use series::SeriesRing;
pub use sketch::QuantileSketch;
pub use trace::{SpanEvent, SpanKind, SpanLog, Tracer};

/// Service-level objective targets for one completion.
///
/// A completion is *good* when its TTFT (time from arrival to first
/// output token) and its TPOT (mean time per output token, Eq. 22) both
/// meet their targets.  Defaults follow common interactive-serving
/// targets: first token within 2 s, sustained decode at ≥ 4 tok/s.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloConfig {
    /// TTFT target in (virtual) seconds.
    pub ttft_s: f64,
    /// TPOT target in (virtual) seconds per token.
    pub tpot_s: f64,
}

impl Default for SloConfig {
    fn default() -> SloConfig {
        SloConfig { ttft_s: 2.0, tpot_s: 0.25 }
    }
}

/// Per-request observability accumulators: streaming sketches for the
/// latency families plus the SLO-goodput counters.  Owned by each
/// [`crate::metrics::Recorder`]; mergeable across replicas (the fleet
/// publishes one merged instance).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RequestObs {
    /// TTFT per completion, in virtual seconds.  Estimated at
    /// completion as `(admit − arrival) + (finish − admit)/o` — queue
    /// wait plus one mean token time — so it is exact under constant
    /// step time and within one step-time spread otherwise.  (The
    /// opt-in tracer records the *exact* first-token clock per span.)
    pub ttft: QuantileSketch,
    /// TPOT per completion (Eq. 22 per request), in virtual seconds.
    pub tpot: QuantileSketch,
    /// Per-step barrier time Δt (Eq. 19), in virtual seconds.
    pub step_time: QuantileSketch,
    /// Per-step instantaneous imbalance `G·max − Σ` (Eq. 2), tokens.
    pub imbalance: QuantileSketch,
    /// Completions meeting both SLO targets.
    pub slo_ok: u64,
    /// Completions evaluated against the SLO.
    pub slo_total: u64,
}

impl RequestObs {
    /// Record one completion's latency figures and score it against the
    /// SLO targets.
    pub fn observe_completion(&mut self, ttft_s: f64, tpot_s: f64, slo: &SloConfig) {
        self.ttft.insert(ttft_s);
        self.tpot.insert(tpot_s);
        self.slo_total += 1;
        if ttft_s <= slo.ttft_s && tpot_s <= slo.tpot_s {
            self.slo_ok += 1;
        }
    }

    /// SLO-goodput ratio: fraction of completions meeting both targets.
    /// Vacuously 1.0 when nothing has completed yet.
    pub fn goodput(&self) -> f64 {
        if self.slo_total == 0 {
            1.0
        } else {
            self.slo_ok as f64 / self.slo_total as f64
        }
    }

    /// Fold another accumulator in (e.g. one per replica).
    pub fn merge(&mut self, other: &RequestObs) {
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.step_time.merge(&other.step_time);
        self.imbalance.merge(&other.imbalance);
        self.slo_ok += other.slo_ok;
        self.slo_total += other.slo_total;
    }

    /// Reset to empty, retaining sketch capacity (for reuse in the
    /// fleet's in-place publish path).
    pub fn clear(&mut self) {
        self.ttft.clear();
        self.tpot.clear();
        self.step_time.clear();
        self.imbalance.clear();
        self.slo_ok = 0;
        self.slo_total = 0;
    }
}

/// Observability block published in the gateway's
/// [`crate::gateway::backend::BackendStats`]: merged request-level
/// accumulators, the fleet round profile, and the active SLO targets.
#[derive(Clone, Debug, Default)]
pub struct ObsStats {
    pub req: RequestObs,
    pub rounds: RoundProfiler,
    pub slo: SloConfig,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn goodput_counts_joint_slo() {
        let slo = SloConfig { ttft_s: 1.0, tpot_s: 0.1 };
        let mut o = RequestObs::default();
        assert_eq!(o.goodput(), 1.0, "vacuous goodput");
        o.observe_completion(0.5, 0.05, &slo); // good
        o.observe_completion(2.0, 0.05, &slo); // ttft miss
        o.observe_completion(0.5, 0.50, &slo); // tpot miss
        o.observe_completion(0.9, 0.09, &slo); // good
        assert_eq!(o.slo_total, 4);
        assert_eq!(o.slo_ok, 2);
        assert!((o.goodput() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn merge_and_clear() {
        let slo = SloConfig::default();
        let mut a = RequestObs::default();
        let mut b = RequestObs::default();
        a.observe_completion(0.1, 0.01, &slo);
        b.observe_completion(9.0, 9.0, &slo);
        a.merge(&b);
        assert_eq!(a.slo_total, 2);
        assert_eq!(a.slo_ok, 1);
        assert_eq!(a.ttft.count(), 2);
        a.clear();
        assert_eq!(a.slo_total, 0);
        assert_eq!(a.ttft.count(), 0);
        assert_eq!(a.goodput(), 1.0);
    }
}
