//! Event-sourced run journal: the deterministic record of every
//! externally-sourced event a fleet run consumes — request arrivals,
//! tier-1 routing decisions (with per-replica decision costs), injected
//! faults, observable health transitions, and replica lifecycle
//! actions — captured at the [`crate::fleet::FleetCore`] choke points
//! into a bounded, zero-steady-state-alloc ring.
//!
//! The journal is the "wire" between a run and its postmortem: because
//! the simulator is strictly deterministic (engine/fleet parity locked
//! to ≤ 1e-9), a journal plus the recorded [`crate::fleet::FleetConfig`]
//! is sufficient to *re-run the exact trajectory* — see
//! [`crate::obs::replay`] for the pinned / counterfactual replay
//! engine.  Two interchangeable encodings are provided:
//!
//! * **binary** (`BFIOJRNL` magic): compact length-prefixed frames,
//!   every `f64` as raw IEEE bits — the lossless archival format;
//! * **JSONL**: one header line (`{"journal":true,...}`) carrying the
//!   config, then one line per event, then an optional trailing
//!   `{"result":{...}}` line — the greppable interchange format served
//!   by the gateway's `GET /v0/journal`.  Floats are emitted in
//!   shortest-round-trip form, so binary ↔ JSONL converts losslessly.
//!
//! Recording is opt-in (`--journal`); with it off the hot path pays a
//! single `Option` check and runs bit-identical to a journal-free
//! build.  When the ring overflows, the *oldest* events are evicted and
//! the `dropped` counter advances — replay refuses a journal with
//! evictions (the trajectory is no longer reconstructable), but the
//! tail is still useful for postmortem reading.

use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::fault::{FaultKind, HealthConfig};
use crate::fleet::{FleetConfig, FleetResult};
use crate::obs::SloConfig;
use crate::sim::predictor::Predictor;
use crate::util::json::{self, Json};
use crate::workload::Drift;

/// Request arrival: `a` = request id, `b` = decode length `o`,
/// `c` = arrival step, `x` = prefill.
pub const EV_ARRIVAL: u8 = 0;
/// Routing decision: `a` = decision sequence number, `c` = chosen
/// replica id + 1 (0 = overflow), `x` = prefill, `costs` = per-replica
/// decision costs over the accepting set (router's own cost model).
pub const EV_ROUTE: u8 = 1;
/// Injected fault: `a` = replica, `b` = kind code
/// ([`FK_CRASH`]/[`FK_STALL`]/[`FK_RECOVER`]), `x` = stall factor.
pub const EV_FAULT: u8 = 2;
/// Observable health transition: `a` = replica, `b` = from-state code,
/// `c` = to-state code (the `crate::obs::series::HEALTH_*` codes).
pub const EV_HEALTH: u8 = 3;
/// Replica lifecycle action: `a` = replica, `b` = op code
/// ([`LC_ADD`]/[`LC_REACTIVATE`]/[`LC_DRAIN`]/[`LC_REMOVE`]),
/// `c` = `(G << 32) | B` shape, `x` = speed (add only).
pub const EV_LIFECYCLE: u8 = 4;

pub const LC_ADD: u8 = 0;
pub const LC_REACTIVATE: u8 = 1;
pub const LC_DRAIN: u8 = 2;
pub const LC_REMOVE: u8 = 3;

pub const FK_CRASH: u64 = 0;
pub const FK_STALL: u64 = 1;
pub const FK_RECOVER: u64 = 2;

/// Encode a [`FaultKind`] as `(code, factor)`.
pub fn fault_code(kind: &FaultKind) -> (u64, f64) {
    match kind {
        FaultKind::Crash => (FK_CRASH, 0.0),
        FaultKind::Stall(f) => (FK_STALL, *f),
        FaultKind::Recover => (FK_RECOVER, 0.0),
    }
}

/// Decode `(code, factor)` back into a [`FaultKind`].
pub fn fault_of(code: u64, x: f64) -> Option<FaultKind> {
    match code {
        FK_CRASH => Some(FaultKind::Crash),
        FK_STALL => Some(FaultKind::Stall(x)),
        FK_RECOVER => Some(FaultKind::Recover),
        _ => None,
    }
}

/// One journaled event.  The payload is a fixed frame of three `u64`
/// scalars + one `f64` (meaning per [`EV_ARRIVAL`]-family kind) plus a
/// per-event cost vector whose capacity is reused on slot eviction, so
/// steady-state recording allocates nothing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JournalEvent {
    pub kind: u8,
    /// Global round the event was applied/recorded at.
    pub round: u64,
    pub a: u64,
    pub b: u64,
    pub c: u64,
    pub x: f64,
    /// `(replica_id, decision_cost)` over the accepting set (routing
    /// decisions only; empty for every other kind).
    pub costs: Vec<(u32, f64)>,
}

/// Bounded event ring: grows lazily to `cap` slots, then evicts the
/// oldest event per record (bumping `dropped`) and reuses the slot
/// in place — zero allocation at steady state.
#[derive(Clone, Debug)]
pub struct JournalRing {
    cap: usize,
    buf: Vec<JournalEvent>,
    head: usize,
    len: usize,
    dropped: u64,
}

impl JournalRing {
    pub fn new(cap: usize) -> JournalRing {
        JournalRing { cap: cap.max(1), buf: Vec::new(), head: 0, len: 0, dropped: 0 }
    }

    /// Claim the next slot (evicting the oldest when full), fill the
    /// scalar frame, and hand back the event so the caller can push
    /// decision costs into its (cleared, capacity-reused) vector.
    pub fn record(
        &mut self,
        kind: u8,
        round: u64,
        a: u64,
        b: u64,
        c: u64,
        x: f64,
    ) -> &mut JournalEvent {
        let idx = if self.len < self.cap {
            let idx = (self.head + self.len) % self.cap;
            if idx == self.buf.len() {
                self.buf.push(JournalEvent::default());
            }
            self.len += 1;
            idx
        } else {
            let idx = self.head;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
            idx
        };
        let ev = &mut self.buf[idx];
        ev.kind = kind;
        ev.round = round;
        ev.a = a;
        ev.b = b;
        ev.c = c;
        ev.x = x;
        ev.costs.clear();
        ev
    }

    /// Events oldest-first.
    pub fn events(&self) -> impl Iterator<Item = &JournalEvent> {
        (0..self.len).map(move |i| &self.buf[(self.head + i) % self.cap])
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Oldest events evicted to make room (0 = the journal is complete
    /// and the run is exactly replayable).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// Everything needed to reconstruct the run besides the events: the
/// tier-1 router *spec* string (parseable by
/// [`FleetConfig::router`], not the display label) and the full fleet
/// config.
#[derive(Clone, Debug)]
pub struct JournalConfig {
    pub router: String,
    pub fleet: FleetConfig,
}

/// The run journal: config + event ring + (once the run finishes) the
/// recorded [`ResultSummary`] that pinned replay must reproduce.
#[derive(Clone, Debug)]
pub struct Journal {
    pub config: JournalConfig,
    pub ring: JournalRing,
    /// Routing decisions recorded so far (monotone; also the `a` field
    /// of the next [`EV_ROUTE`] event).
    pub route_seq: u64,
    pub result: Option<ResultSummary>,
}

impl Journal {
    pub fn new(router: &str, fleet: FleetConfig, cap: usize) -> Journal {
        Journal {
            config: JournalConfig { router: router.to_string(), fleet },
            ring: JournalRing::new(cap),
            route_seq: 0,
            result: None,
        }
    }

    pub fn shared(router: &str, fleet: FleetConfig, cap: usize) -> Arc<Mutex<Journal>> {
        Arc::new(Mutex::new(Journal::new(router, fleet, cap)))
    }

    pub fn record_arrival(&mut self, round: u64, id: u64, arrival_step: u64, prefill: f64, o: u64) {
        self.ring.record(EV_ARRIVAL, round, id, o, arrival_step, prefill);
    }

    /// Record a routing decision (`chosen = None` ⇒ overflow) and hand
    /// back the event's cost vector for the caller to fill with the
    /// accepting set's decision costs.
    pub fn record_route(
        &mut self,
        round: u64,
        prefill: f64,
        chosen: Option<usize>,
    ) -> &mut Vec<(u32, f64)> {
        let seq = self.route_seq;
        self.route_seq += 1;
        let code = chosen.map_or(0, |id| id as u64 + 1);
        let ev = self.ring.record(EV_ROUTE, round, seq, 0, code, prefill);
        &mut ev.costs
    }

    pub fn record_fault(&mut self, round: u64, replica: usize, kind: &FaultKind) {
        let (code, x) = fault_code(kind);
        self.ring.record(EV_FAULT, round, replica as u64, code, 0, x);
    }

    pub fn record_health(&mut self, round: u64, replica: usize, from: u8, to: u8) {
        self.ring
            .record(EV_HEALTH, round, replica as u64, from as u64, to as u64, 0.0);
    }

    pub fn record_lifecycle(
        &mut self,
        round: u64,
        replica: usize,
        op: u8,
        g: usize,
        b: usize,
        speed: f64,
    ) {
        let shape = ((g as u64) << 32) | (b as u64 & 0xffff_ffff);
        self.ring
            .record(EV_LIFECYCLE, round, replica as u64, op as u64, shape, speed);
    }

    pub fn set_result(&mut self, summary: ResultSummary) {
        self.result = Some(summary);
    }

    pub fn dropped(&self) -> u64 {
        self.ring.dropped()
    }

    /// The recorded routing decisions in sequence order: chosen replica
    /// id + 1, 0 = overflow.  This is what pinned replay forces.
    pub fn route_decisions(&self) -> Vec<u64> {
        self.ring
            .events()
            .filter(|e| e.kind == EV_ROUTE)
            .map(|e| e.c)
            .collect()
    }

    /// Write to `path`: JSONL when the extension is `.jsonl`/`.json`,
    /// the binary frame otherwise.
    pub fn save(&self, path: &Path) -> Result<()> {
        let ext = path.extension().and_then(|e| e.to_str()).unwrap_or("");
        let bytes = if ext.eq_ignore_ascii_case("jsonl") || ext.eq_ignore_ascii_case("json") {
            self.to_jsonl().into_bytes()
        } else {
            self.to_binary()
        };
        std::fs::write(path, bytes)
            .with_context(|| format!("journal: writing {}", path.display()))
    }

    /// Read from `path`, sniffing the format by the binary magic.
    pub fn load(path: &Path) -> Result<Journal> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("journal: reading {}", path.display()))?;
        if bytes.starts_with(MAGIC) {
            Journal::from_binary(&bytes)
        } else {
            let text = String::from_utf8(bytes)
                .with_context(|| format!("journal: {} is not UTF-8 JSONL", path.display()))?;
            Journal::from_jsonl(&text)
        }
    }
}

const MAGIC: &[u8] = b"BFIOJRNL";
const VERSION: u32 = 1;

// ---------------------------------------------------------------------------
// Binary codec
// ---------------------------------------------------------------------------

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("journal: truncated binary frame at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())
            .context("journal: non-UTF-8 string in binary frame")?)
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// `(tag, payload)` for a [`Drift`]; shared by both codecs.
fn drift_enc(d: &Drift) -> (u8, Vec<f64>) {
    match d {
        Drift::Unit => (0, Vec::new()),
        Drift::Zero => (1, Vec::new()),
        Drift::Const(c) => (2, vec![*c]),
        Drift::Speculative(m) => (3, vec![*m]),
        Drift::Cycle(xs) => (4, xs.clone()),
        Drift::Decay { d0, rate } => (5, vec![*d0, *rate]),
    }
}

fn drift_dec(tag: u8, vals: &[f64]) -> Result<Drift> {
    let need = |n: usize| -> Result<()> {
        if vals.len() < n {
            bail!("journal: drift tag {tag} needs {n} values, got {}", vals.len());
        }
        Ok(())
    };
    Ok(match tag {
        0 => Drift::Unit,
        1 => Drift::Zero,
        2 => {
            need(1)?;
            Drift::Const(vals[0])
        }
        3 => {
            need(1)?;
            Drift::Speculative(vals[0])
        }
        4 => Drift::Cycle(vals.to_vec()),
        5 => {
            need(2)?;
            Drift::Decay { d0: vals[0], rate: vals[1] }
        }
        _ => bail!("journal: unknown drift tag {tag}"),
    })
}

fn predictor_enc(p: &Predictor) -> (u8, Vec<f64>) {
    match p {
        Predictor::Oracle => (0, Vec::new()),
        Predictor::WindowOracle => (1, Vec::new()),
        Predictor::Noisy { sigma_frac, miss_prob } => (2, vec![*sigma_frac, *miss_prob]),
        Predictor::Pessimistic => (3, Vec::new()),
    }
}

fn predictor_dec(tag: u8, vals: &[f64]) -> Result<Predictor> {
    Ok(match tag {
        0 => Predictor::Oracle,
        1 => Predictor::WindowOracle,
        2 => {
            if vals.len() < 2 {
                bail!("journal: predictor tag 2 needs 2 values");
            }
            Predictor::Noisy { sigma_frac: vals[0], miss_prob: vals[1] }
        }
        3 => Predictor::Pessimistic,
        _ => bail!("journal: unknown predictor tag {tag}"),
    })
}

fn put_tagged(out: &mut Vec<u8>, tag: u8, vals: &[f64]) {
    out.push(tag);
    put_u32(out, vals.len() as u32);
    for &v in vals {
        put_f64(out, v);
    }
}

fn take_tagged(r: &mut Reader) -> Result<(u8, Vec<f64>)> {
    let tag = r.u8()?;
    let n = r.u32()? as usize;
    let mut vals = Vec::with_capacity(n);
    for _ in 0..n {
        vals.push(r.f64()?);
    }
    Ok((tag, vals))
}

fn put_fleet_config(out: &mut Vec<u8>, c: &FleetConfig) {
    put_u64(out, c.g as u64);
    put_u64(out, c.b as u64);
    put_str(out, &c.policy);
    let (tag, vals) = drift_enc(&c.drift);
    put_tagged(out, tag, &vals);
    put_f64(out, c.c_overhead);
    put_f64(out, c.t_token);
    put_u32(out, c.speeds.len() as u32);
    for &s in &c.speeds {
        put_f64(out, s);
    }
    match &c.shapes {
        None => out.push(0),
        Some(shapes) => {
            out.push(1);
            put_u32(out, shapes.len() as u32);
            for &(g, b) in shapes {
                put_u64(out, g as u64);
                put_u64(out, b as u64);
            }
        }
    }
    put_u64(out, c.threads as u64);
    put_u64(out, c.seed);
    put_f64(out, c.slo.ttft_s);
    put_f64(out, c.slo.tpot_s);
    put_u64(out, c.max_rounds);
    put_u64(out, c.warmup_rounds);
    out.push(c.record_completions as u8);
    let (tag, vals) = predictor_enc(&c.predictor);
    put_tagged(out, tag, &vals);
    put_f64(out, c.health.ewma_alpha);
    put_f64(out, c.health.suspect_ratio);
    put_u32(out, c.health.miss_limit);
    put_u32(out, c.health.probe_rounds);
    put_f64(out, c.health.suspect_penalty);
    put_f64(out, c.health.probe_penalty);
    put_u64(out, c.series_window);
    put_u64(out, c.series_cap as u64);
}

fn take_fleet_config(r: &mut Reader) -> Result<FleetConfig> {
    let g = r.u64()? as usize;
    let b = r.u64()? as usize;
    let policy = r.str()?;
    let (tag, vals) = take_tagged(r)?;
    let drift = drift_dec(tag, &vals)?;
    let c_overhead = r.f64()?;
    let t_token = r.f64()?;
    let n = r.u32()? as usize;
    let mut speeds = Vec::with_capacity(n);
    for _ in 0..n {
        speeds.push(r.f64()?);
    }
    let shapes = match r.u8()? {
        0 => None,
        _ => {
            let n = r.u32()? as usize;
            let mut shapes = Vec::with_capacity(n);
            for _ in 0..n {
                let g = r.u64()? as usize;
                let b = r.u64()? as usize;
                shapes.push((g, b));
            }
            Some(shapes)
        }
    };
    let threads = r.u64()? as usize;
    let seed = r.u64()?;
    let slo = SloConfig { ttft_s: r.f64()?, tpot_s: r.f64()? };
    let max_rounds = r.u64()?;
    let warmup_rounds = r.u64()?;
    let record_completions = r.u8()? != 0;
    let (tag, vals) = take_tagged(r)?;
    let predictor = predictor_dec(tag, &vals)?;
    let health = HealthConfig {
        ewma_alpha: r.f64()?,
        suspect_ratio: r.f64()?,
        miss_limit: r.u32()?,
        probe_rounds: r.u32()?,
        suspect_penalty: r.f64()?,
        probe_penalty: r.f64()?,
    };
    let series_window = r.u64()?;
    let series_cap = r.u64()? as usize;
    Ok(FleetConfig {
        g,
        b,
        policy,
        drift,
        c_overhead,
        t_token,
        speeds,
        shapes,
        threads,
        seed,
        slo,
        max_rounds,
        warmup_rounds,
        record_completions,
        predictor,
        health,
        series_window,
        series_cap,
    })
}

impl Journal {
    pub fn to_binary(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + self.ring.len() * 48);
        out.extend_from_slice(MAGIC);
        put_u32(&mut out, VERSION);
        put_str(&mut out, &self.config.router);
        put_fleet_config(&mut out, &self.config.fleet);
        put_u64(&mut out, self.ring.cap() as u64);
        put_u64(&mut out, self.ring.dropped());
        put_u64(&mut out, self.route_seq);
        put_u64(&mut out, self.ring.len() as u64);
        for ev in self.ring.events() {
            out.push(ev.kind);
            put_u64(&mut out, ev.round);
            put_u64(&mut out, ev.a);
            put_u64(&mut out, ev.b);
            put_u64(&mut out, ev.c);
            put_f64(&mut out, ev.x);
            put_u32(&mut out, ev.costs.len() as u32);
            for &(id, cost) in &ev.costs {
                put_u32(&mut out, id);
                put_f64(&mut out, cost);
            }
        }
        match &self.result {
            None => out.push(0),
            Some(s) => {
                out.push(1);
                put_summary(&mut out, s);
            }
        }
        out
    }

    pub fn from_binary(bytes: &[u8]) -> Result<Journal> {
        if !bytes.starts_with(MAGIC) {
            bail!("journal: bad magic (not a BFIOJRNL binary frame)");
        }
        let mut r = Reader { b: bytes, pos: MAGIC.len() };
        let version = r.u32()?;
        if version != VERSION {
            bail!("journal: unsupported version {version} (expected {VERSION})");
        }
        let router = r.str()?;
        let fleet = take_fleet_config(&mut r)?;
        let cap = r.u64()? as usize;
        let dropped = r.u64()?;
        let route_seq = r.u64()?;
        let n = r.u64()? as usize;
        let mut ring = JournalRing::new(cap.max(n));
        for _ in 0..n {
            let kind = r.u8()?;
            let round = r.u64()?;
            let a = r.u64()?;
            let b = r.u64()?;
            let c = r.u64()?;
            let x = r.f64()?;
            let ev = ring.record(kind, round, a, b, c, x);
            let m = r.u32()? as usize;
            for _ in 0..m {
                let id = r.u32()?;
                let cost = r.f64()?;
                ev.costs.push((id, cost));
            }
        }
        ring.cap = cap.max(1);
        ring.dropped = dropped;
        let result = match r.u8()? {
            0 => None,
            _ => Some(take_summary(&mut r)?),
        };
        Ok(Journal {
            config: JournalConfig { router, fleet },
            ring,
            route_seq,
            result,
        })
    }
}

// ---------------------------------------------------------------------------
// JSONL codec
// ---------------------------------------------------------------------------

fn tagged_json(tag: u8, vals: &[f64]) -> Json {
    json::obj(vec![
        ("tag", json::num(tag as f64)),
        ("vals", json::nums(vals)),
    ])
}

fn tagged_of(v: &Json, what: &str) -> Result<(u8, Vec<f64>)> {
    let tag = v
        .get("tag")
        .and_then(|t| t.as_u64())
        .with_context(|| format!("journal: {what}.tag missing"))? as u8;
    let vals = v
        .get("vals")
        .and_then(|t| t.as_arr())
        .with_context(|| format!("journal: {what}.vals missing"))?
        .iter()
        .map(|x| x.as_f64().with_context(|| format!("journal: {what}.vals entry")))
        .collect::<Result<Vec<f64>>>()?;
    Ok((tag, vals))
}

fn jf(v: &Json, k: &str) -> Result<f64> {
    v.get(k)
        .and_then(|x| x.as_f64())
        .with_context(|| format!("journal: missing number {k:?}"))
}

fn ju(v: &Json, k: &str) -> Result<u64> {
    v.get(k)
        .and_then(|x| x.as_u64())
        .with_context(|| format!("journal: missing integer {k:?}"))
}

fn jstr(v: &Json, k: &str) -> Result<String> {
    Ok(v.get(k)
        .and_then(|x| x.as_str())
        .with_context(|| format!("journal: missing string {k:?}"))?
        .to_string())
}

fn fleet_config_json(c: &FleetConfig) -> Json {
    let (dtag, dvals) = drift_enc(&c.drift);
    let (ptag, pvals) = predictor_enc(&c.predictor);
    let shapes = match &c.shapes {
        None => Json::Null,
        Some(shapes) => json::arr(shapes.iter().map(|&(g, b)| {
            json::arr(vec![json::num(g as f64), json::num(b as f64)])
        })),
    };
    json::obj(vec![
        ("g", json::num(c.g as f64)),
        ("b", json::num(c.b as f64)),
        ("policy", json::s(&c.policy)),
        ("drift", tagged_json(dtag, &dvals)),
        ("c_overhead", json::num(c.c_overhead)),
        ("t_token", json::num(c.t_token)),
        ("speeds", json::nums(&c.speeds)),
        ("shapes", shapes),
        ("threads", json::num(c.threads as f64)),
        ("seed", json::num(c.seed as f64)),
        (
            "slo",
            json::obj(vec![
                ("ttft_s", json::num(c.slo.ttft_s)),
                ("tpot_s", json::num(c.slo.tpot_s)),
            ]),
        ),
        ("max_rounds", json::num(c.max_rounds as f64)),
        ("warmup_rounds", json::num(c.warmup_rounds as f64)),
        ("record_completions", Json::Bool(c.record_completions)),
        ("predictor", tagged_json(ptag, &pvals)),
        (
            "health",
            json::obj(vec![
                ("ewma_alpha", json::num(c.health.ewma_alpha)),
                ("suspect_ratio", json::num(c.health.suspect_ratio)),
                ("miss_limit", json::num(c.health.miss_limit as f64)),
                ("probe_rounds", json::num(c.health.probe_rounds as f64)),
                ("suspect_penalty", json::num(c.health.suspect_penalty)),
                ("probe_penalty", json::num(c.health.probe_penalty)),
            ]),
        ),
        ("series_window", json::num(c.series_window as f64)),
        ("series_cap", json::num(c.series_cap as f64)),
    ])
}

fn fleet_config_of(v: &Json) -> Result<FleetConfig> {
    let (dtag, dvals) = tagged_of(
        v.get("drift").context("journal: missing fleet.drift")?,
        "drift",
    )?;
    let (ptag, pvals) = tagged_of(
        v.get("predictor").context("journal: missing fleet.predictor")?,
        "predictor",
    )?;
    let shapes = match v.get("shapes") {
        None | Some(Json::Null) => None,
        Some(s) => Some(
            s.as_arr()
                .context("journal: fleet.shapes must be an array")?
                .iter()
                .map(|pair| {
                    let g = pair
                        .idx(0)
                        .and_then(|x| x.as_usize())
                        .context("journal: shape entry g")?;
                    let b = pair
                        .idx(1)
                        .and_then(|x| x.as_usize())
                        .context("journal: shape entry b")?;
                    Ok((g, b))
                })
                .collect::<Result<Vec<(usize, usize)>>>()?,
        ),
    };
    let speeds = v
        .get("speeds")
        .and_then(|s| s.as_arr())
        .context("journal: missing fleet.speeds")?
        .iter()
        .map(|x| x.as_f64().context("journal: fleet.speeds entry"))
        .collect::<Result<Vec<f64>>>()?;
    let slo_v = v.get("slo").context("journal: missing fleet.slo")?;
    let health_v = v.get("health").context("journal: missing fleet.health")?;
    Ok(FleetConfig {
        g: ju(v, "g")? as usize,
        b: ju(v, "b")? as usize,
        policy: jstr(v, "policy")?,
        drift: drift_dec(dtag, &dvals)?,
        c_overhead: jf(v, "c_overhead")?,
        t_token: jf(v, "t_token")?,
        speeds,
        shapes,
        threads: ju(v, "threads")? as usize,
        seed: ju(v, "seed")?,
        slo: SloConfig { ttft_s: jf(slo_v, "ttft_s")?, tpot_s: jf(slo_v, "tpot_s")? },
        max_rounds: ju(v, "max_rounds")?,
        warmup_rounds: ju(v, "warmup_rounds")?,
        record_completions: v
            .get("record_completions")
            .and_then(|x| x.as_bool())
            .unwrap_or(false),
        predictor: predictor_dec(ptag, &pvals)?,
        health: HealthConfig {
            ewma_alpha: jf(health_v, "ewma_alpha")?,
            suspect_ratio: jf(health_v, "suspect_ratio")?,
            miss_limit: ju(health_v, "miss_limit")? as u32,
            probe_rounds: ju(health_v, "probe_rounds")? as u32,
            suspect_penalty: jf(health_v, "suspect_penalty")?,
            probe_penalty: jf(health_v, "probe_penalty")?,
        },
        series_window: ju(v, "series_window")?,
        series_cap: ju(v, "series_cap")? as usize,
    })
}

fn event_json(ev: &JournalEvent) -> Json {
    let mut pairs = vec![
        ("kind", json::num(ev.kind as f64)),
        ("round", json::num(ev.round as f64)),
        ("a", json::num(ev.a as f64)),
        ("b", json::num(ev.b as f64)),
        ("c", json::num(ev.c as f64)),
        ("x", json::num(ev.x)),
    ];
    if !ev.costs.is_empty() {
        pairs.push((
            "costs",
            json::arr(ev.costs.iter().map(|&(id, cost)| {
                json::arr(vec![json::num(id as f64), json::num(cost)])
            })),
        ));
    }
    json::obj(pairs)
}

fn event_of(v: &Json) -> Result<JournalEvent> {
    let mut ev = JournalEvent {
        kind: ju(v, "kind")? as u8,
        round: ju(v, "round")?,
        a: ju(v, "a")?,
        b: ju(v, "b")?,
        c: ju(v, "c")?,
        x: jf(v, "x")?,
        costs: Vec::new(),
    };
    if let Some(costs) = v.get("costs").and_then(|c| c.as_arr()) {
        for pair in costs {
            let id = pair
                .idx(0)
                .and_then(|x| x.as_u64())
                .context("journal: cost entry id")? as u32;
            let cost = pair
                .idx(1)
                .and_then(|x| x.as_f64())
                .context("journal: cost entry value")?;
            ev.costs.push((id, cost));
        }
    }
    Ok(ev)
}

impl Journal {
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let header = json::obj(vec![
            ("journal", Json::Bool(true)),
            ("version", json::num(VERSION as f64)),
            ("router", json::s(&self.config.router)),
            ("cap", json::num(self.ring.cap() as f64)),
            ("dropped", json::num(self.ring.dropped() as f64)),
            ("route_seq", json::num(self.route_seq as f64)),
            ("fleet", fleet_config_json(&self.config.fleet)),
        ]);
        out.push_str(&header.to_string());
        out.push('\n');
        for ev in self.ring.events() {
            out.push_str(&event_json(ev).to_string());
            out.push('\n');
        }
        if let Some(s) = &self.result {
            out.push_str(&json::obj(vec![("result", summary_json(s))]).to_string());
            out.push('\n');
        }
        out
    }

    pub fn from_jsonl(text: &str) -> Result<Journal> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = Json::parse(lines.next().context("journal: empty JSONL")?)
            .map_err(|e| anyhow::anyhow!("journal: bad JSONL header: {e:?}"))?;
        if header.get("journal").and_then(|x| x.as_bool()) != Some(true) {
            bail!("journal: JSONL header is missing \"journal\":true");
        }
        let version = ju(&header, "version")?;
        if version != VERSION as u64 {
            bail!("journal: unsupported version {version} (expected {VERSION})");
        }
        let router = jstr(&header, "router")?;
        let cap = ju(&header, "cap")? as usize;
        let dropped = ju(&header, "dropped")?;
        let route_seq = ju(&header, "route_seq")?;
        let fleet = fleet_config_of(
            header.get("fleet").context("journal: header missing fleet config")?,
        )?;
        let mut events: Vec<JournalEvent> = Vec::new();
        let mut result = None;
        for line in lines {
            let v = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("journal: bad JSONL line: {e:?}"))?;
            if let Some(r) = v.get("result") {
                result = Some(summary_of(r)?);
            } else {
                events.push(event_of(&v)?);
            }
        }
        let mut ring = JournalRing::new(cap.max(events.len()));
        for e in events {
            let ev = ring.record(e.kind, e.round, e.a, e.b, e.c, e.x);
            ev.costs = e.costs;
        }
        ring.cap = cap.max(1);
        ring.dropped = dropped;
        Ok(Journal {
            config: JournalConfig { router, fleet },
            ring,
            route_seq,
            result,
        })
    }
}

// ---------------------------------------------------------------------------
// Result summary
// ---------------------------------------------------------------------------

/// One replica's line in the recorded outcome.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplicaSummary {
    pub id: u64,
    pub speed: f64,
    pub routed: u64,
    pub completed: u64,
    pub executed: u64,
    pub clock_s: f64,
    pub energy_j: f64,
    pub attributed_waste_j: f64,
}

/// The scalar surface of a [`FleetResult`], recorded into the journal
/// when the run finishes.  Pinned replay must reproduce it — integers
/// exactly, floats to ≤ 1e-9 relative ([`ResultSummary::diff`] is the
/// gate `bfio replay --check` runs).
#[derive(Clone, Debug, PartialEq)]
pub struct ResultSummary {
    pub router: String,
    pub policy: String,
    pub rounds: u64,
    pub steps: u64,
    pub submitted: u64,
    pub completed: u64,
    pub total_tokens: f64,
    pub makespan_s: f64,
    pub clock_ratio: f64,
    pub energy_j: f64,
    pub avg_imbalance: f64,
    pub tpot_s: f64,
    pub mean_queue_wait_s: f64,
    pub throughput_tps: f64,
    pub leftover_waiting: u64,
    pub slo_goodput: f64,
    pub crashes: u64,
    pub stalls: u64,
    pub recoveries: u64,
    pub requeued: u64,
    pub shed: u64,
    pub regret_decisions: u64,
    pub regret_audited: u64,
    pub regret_cumulative: f64,
    pub max_regret: f64,
    pub attributed_waste_j: f64,
    pub per_replica: Vec<ReplicaSummary>,
}

impl ResultSummary {
    pub fn from_result(r: &FleetResult) -> ResultSummary {
        ResultSummary {
            router: r.router.clone(),
            policy: r.policy.clone(),
            rounds: r.rounds,
            steps: r.steps,
            submitted: r.submitted,
            completed: r.completed,
            total_tokens: r.total_tokens,
            makespan_s: r.makespan_s,
            clock_ratio: r.clock_ratio,
            energy_j: r.energy_j,
            avg_imbalance: r.avg_imbalance,
            tpot_s: r.tpot_s,
            mean_queue_wait_s: r.mean_queue_wait_s,
            throughput_tps: r.throughput_tps,
            leftover_waiting: r.leftover_waiting as u64,
            slo_goodput: r.slo_goodput,
            crashes: r.crashes,
            stalls: r.stalls,
            recoveries: r.recoveries,
            requeued: r.requeued,
            shed: r.shed,
            regret_decisions: r.regret.decisions,
            regret_audited: r.regret.audited,
            regret_cumulative: r.regret.cumulative(),
            max_regret: r.regret.max_regret,
            attributed_waste_j: r.attributed_waste_j,
            per_replica: r
                .per_replica
                .iter()
                .map(|p| ReplicaSummary {
                    id: p.id as u64,
                    speed: p.speed,
                    routed: p.routed,
                    completed: p.completed,
                    executed: p.executed,
                    clock_s: p.clock_s,
                    energy_j: p.report.total_energy_j,
                    attributed_waste_j: p.attributed_waste_j,
                })
                .collect(),
        }
    }

    /// Post-warmup joules per token (0 with no tokens).
    pub fn energy_per_token_j(&self) -> f64 {
        if self.total_tokens > 0.0 {
            self.energy_j / self.total_tokens
        } else {
            0.0
        }
    }

    /// Field-by-field mismatches against `other`: integers must be
    /// exact, floats within 1e-9 relative (the house determinism
    /// tolerance).  Empty ⇒ the runs are the same trajectory.
    pub fn diff(&self, other: &ResultSummary) -> Vec<String> {
        fn int(out: &mut Vec<String>, name: &str, a: u64, b: u64) {
            if a != b {
                out.push(format!("{name}: {a} vs {b}"));
            }
        }
        fn flt(out: &mut Vec<String>, name: &str, a: f64, b: f64) {
            let scale = 1.0_f64.max(a.abs()).max(b.abs());
            if (a - b).abs() > 1e-9 * scale {
                out.push(format!("{name}: {a:.17e} vs {b:.17e}"));
            }
        }
        let mut out = Vec::new();
        if self.router != other.router {
            out.push(format!("router: {:?} vs {:?}", self.router, other.router));
        }
        if self.policy != other.policy {
            out.push(format!("policy: {:?} vs {:?}", self.policy, other.policy));
        }
        int(&mut out, "rounds", self.rounds, other.rounds);
        int(&mut out, "steps", self.steps, other.steps);
        int(&mut out, "submitted", self.submitted, other.submitted);
        int(&mut out, "completed", self.completed, other.completed);
        int(&mut out, "leftover_waiting", self.leftover_waiting, other.leftover_waiting);
        int(&mut out, "crashes", self.crashes, other.crashes);
        int(&mut out, "stalls", self.stalls, other.stalls);
        int(&mut out, "recoveries", self.recoveries, other.recoveries);
        int(&mut out, "requeued", self.requeued, other.requeued);
        int(&mut out, "shed", self.shed, other.shed);
        int(&mut out, "regret_decisions", self.regret_decisions, other.regret_decisions);
        int(&mut out, "regret_audited", self.regret_audited, other.regret_audited);
        flt(&mut out, "total_tokens", self.total_tokens, other.total_tokens);
        flt(&mut out, "makespan_s", self.makespan_s, other.makespan_s);
        flt(&mut out, "clock_ratio", self.clock_ratio, other.clock_ratio);
        flt(&mut out, "energy_j", self.energy_j, other.energy_j);
        flt(&mut out, "avg_imbalance", self.avg_imbalance, other.avg_imbalance);
        flt(&mut out, "tpot_s", self.tpot_s, other.tpot_s);
        flt(&mut out, "mean_queue_wait_s", self.mean_queue_wait_s, other.mean_queue_wait_s);
        flt(&mut out, "throughput_tps", self.throughput_tps, other.throughput_tps);
        flt(&mut out, "slo_goodput", self.slo_goodput, other.slo_goodput);
        flt(&mut out, "regret_cumulative", self.regret_cumulative, other.regret_cumulative);
        flt(&mut out, "max_regret", self.max_regret, other.max_regret);
        flt(&mut out, "attributed_waste_j", self.attributed_waste_j, other.attributed_waste_j);
        if self.per_replica.len() != other.per_replica.len() {
            out.push(format!(
                "per_replica: {} vs {} replicas",
                self.per_replica.len(),
                other.per_replica.len()
            ));
            return out;
        }
        for (a, b) in self.per_replica.iter().zip(&other.per_replica) {
            let r = a.id;
            int(&mut out, &format!("r{r}.id"), a.id, b.id);
            int(&mut out, &format!("r{r}.routed"), a.routed, b.routed);
            int(&mut out, &format!("r{r}.completed"), a.completed, b.completed);
            int(&mut out, &format!("r{r}.executed"), a.executed, b.executed);
            flt(&mut out, &format!("r{r}.speed"), a.speed, b.speed);
            flt(&mut out, &format!("r{r}.clock_s"), a.clock_s, b.clock_s);
            flt(&mut out, &format!("r{r}.energy_j"), a.energy_j, b.energy_j);
            flt(
                &mut out,
                &format!("r{r}.attributed_waste_j"),
                a.attributed_waste_j,
                b.attributed_waste_j,
            );
        }
        out
    }
}

fn summary_json(s: &ResultSummary) -> Json {
    json::obj(vec![
        ("router", json::s(&s.router)),
        ("policy", json::s(&s.policy)),
        ("rounds", json::num(s.rounds as f64)),
        ("steps", json::num(s.steps as f64)),
        ("submitted", json::num(s.submitted as f64)),
        ("completed", json::num(s.completed as f64)),
        ("total_tokens", json::num(s.total_tokens)),
        ("makespan_s", json::num(s.makespan_s)),
        ("clock_ratio", json::num(s.clock_ratio)),
        ("energy_j", json::num(s.energy_j)),
        ("avg_imbalance", json::num(s.avg_imbalance)),
        ("tpot_s", json::num(s.tpot_s)),
        ("mean_queue_wait_s", json::num(s.mean_queue_wait_s)),
        ("throughput_tps", json::num(s.throughput_tps)),
        ("leftover_waiting", json::num(s.leftover_waiting as f64)),
        ("slo_goodput", json::num(s.slo_goodput)),
        ("crashes", json::num(s.crashes as f64)),
        ("stalls", json::num(s.stalls as f64)),
        ("recoveries", json::num(s.recoveries as f64)),
        ("requeued", json::num(s.requeued as f64)),
        ("shed", json::num(s.shed as f64)),
        ("regret_decisions", json::num(s.regret_decisions as f64)),
        ("regret_audited", json::num(s.regret_audited as f64)),
        ("regret_cumulative", json::num(s.regret_cumulative)),
        ("max_regret", json::num(s.max_regret)),
        ("attributed_waste_j", json::num(s.attributed_waste_j)),
        (
            "per_replica",
            json::arr(s.per_replica.iter().map(|p| {
                json::obj(vec![
                    ("id", json::num(p.id as f64)),
                    ("speed", json::num(p.speed)),
                    ("routed", json::num(p.routed as f64)),
                    ("completed", json::num(p.completed as f64)),
                    ("executed", json::num(p.executed as f64)),
                    ("clock_s", json::num(p.clock_s)),
                    ("energy_j", json::num(p.energy_j)),
                    ("attributed_waste_j", json::num(p.attributed_waste_j)),
                ])
            })),
        ),
    ])
}

fn summary_of(v: &Json) -> Result<ResultSummary> {
    let per_replica = v
        .get("per_replica")
        .and_then(|p| p.as_arr())
        .context("journal: result missing per_replica")?
        .iter()
        .map(|p| {
            Ok(ReplicaSummary {
                id: ju(p, "id")?,
                speed: jf(p, "speed")?,
                routed: ju(p, "routed")?,
                completed: ju(p, "completed")?,
                executed: ju(p, "executed")?,
                clock_s: jf(p, "clock_s")?,
                energy_j: jf(p, "energy_j")?,
                attributed_waste_j: jf(p, "attributed_waste_j")?,
            })
        })
        .collect::<Result<Vec<ReplicaSummary>>>()?;
    Ok(ResultSummary {
        router: jstr(v, "router")?,
        policy: jstr(v, "policy")?,
        rounds: ju(v, "rounds")?,
        steps: ju(v, "steps")?,
        submitted: ju(v, "submitted")?,
        completed: ju(v, "completed")?,
        total_tokens: jf(v, "total_tokens")?,
        makespan_s: jf(v, "makespan_s")?,
        clock_ratio: jf(v, "clock_ratio")?,
        energy_j: jf(v, "energy_j")?,
        avg_imbalance: jf(v, "avg_imbalance")?,
        tpot_s: jf(v, "tpot_s")?,
        mean_queue_wait_s: jf(v, "mean_queue_wait_s")?,
        throughput_tps: jf(v, "throughput_tps")?,
        leftover_waiting: ju(v, "leftover_waiting")?,
        slo_goodput: jf(v, "slo_goodput")?,
        crashes: ju(v, "crashes")?,
        stalls: ju(v, "stalls")?,
        recoveries: ju(v, "recoveries")?,
        requeued: ju(v, "requeued")?,
        shed: ju(v, "shed")?,
        regret_decisions: ju(v, "regret_decisions")?,
        regret_audited: ju(v, "regret_audited")?,
        regret_cumulative: jf(v, "regret_cumulative")?,
        max_regret: jf(v, "max_regret")?,
        attributed_waste_j: jf(v, "attributed_waste_j")?,
        per_replica,
    })
}

fn put_summary(out: &mut Vec<u8>, s: &ResultSummary) {
    put_str(out, &s.router);
    put_str(out, &s.policy);
    put_u64(out, s.rounds);
    put_u64(out, s.steps);
    put_u64(out, s.submitted);
    put_u64(out, s.completed);
    put_f64(out, s.total_tokens);
    put_f64(out, s.makespan_s);
    put_f64(out, s.clock_ratio);
    put_f64(out, s.energy_j);
    put_f64(out, s.avg_imbalance);
    put_f64(out, s.tpot_s);
    put_f64(out, s.mean_queue_wait_s);
    put_f64(out, s.throughput_tps);
    put_u64(out, s.leftover_waiting);
    put_f64(out, s.slo_goodput);
    put_u64(out, s.crashes);
    put_u64(out, s.stalls);
    put_u64(out, s.recoveries);
    put_u64(out, s.requeued);
    put_u64(out, s.shed);
    put_u64(out, s.regret_decisions);
    put_u64(out, s.regret_audited);
    put_f64(out, s.regret_cumulative);
    put_f64(out, s.max_regret);
    put_f64(out, s.attributed_waste_j);
    put_u32(out, s.per_replica.len() as u32);
    for p in &s.per_replica {
        put_u64(out, p.id);
        put_f64(out, p.speed);
        put_u64(out, p.routed);
        put_u64(out, p.completed);
        put_u64(out, p.executed);
        put_f64(out, p.clock_s);
        put_f64(out, p.energy_j);
        put_f64(out, p.attributed_waste_j);
    }
}

fn take_summary(r: &mut Reader) -> Result<ResultSummary> {
    let router = r.str()?;
    let policy = r.str()?;
    let rounds = r.u64()?;
    let steps = r.u64()?;
    let submitted = r.u64()?;
    let completed = r.u64()?;
    let total_tokens = r.f64()?;
    let makespan_s = r.f64()?;
    let clock_ratio = r.f64()?;
    let energy_j = r.f64()?;
    let avg_imbalance = r.f64()?;
    let tpot_s = r.f64()?;
    let mean_queue_wait_s = r.f64()?;
    let throughput_tps = r.f64()?;
    let leftover_waiting = r.u64()?;
    let slo_goodput = r.f64()?;
    let crashes = r.u64()?;
    let stalls = r.u64()?;
    let recoveries = r.u64()?;
    let requeued = r.u64()?;
    let shed = r.u64()?;
    let regret_decisions = r.u64()?;
    let regret_audited = r.u64()?;
    let regret_cumulative = r.f64()?;
    let max_regret = r.f64()?;
    let attributed_waste_j = r.f64()?;
    let n = r.u32()? as usize;
    let mut per_replica = Vec::with_capacity(n);
    for _ in 0..n {
        per_replica.push(ReplicaSummary {
            id: r.u64()?,
            speed: r.f64()?,
            routed: r.u64()?,
            completed: r.u64()?,
            executed: r.u64()?,
            clock_s: r.f64()?,
            energy_j: r.f64()?,
            attributed_waste_j: r.f64()?,
        });
    }
    Ok(ResultSummary {
        router,
        policy,
        rounds,
        steps,
        submitted,
        completed,
        total_tokens,
        makespan_s,
        clock_ratio,
        energy_j,
        avg_imbalance,
        tpot_s,
        mean_queue_wait_s,
        throughput_tps,
        leftover_waiting,
        slo_goodput,
        crashes,
        stalls,
        recoveries,
        requeued,
        shed,
        regret_decisions,
        regret_audited,
        regret_cumulative,
        max_regret,
        attributed_waste_j,
        per_replica,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> Journal {
        let mut cfg = FleetConfig::uniform(2, 2, 2, "fcfs");
        cfg.seed = 7;
        cfg.drift = Drift::Decay { d0: 2.0, rate: 0.125 };
        cfg.predictor = Predictor::Noisy { sigma_frac: 0.25, miss_prob: 0.1 };
        cfg.shapes = Some(vec![(2, 2), (4, 1)]);
        let mut j = Journal::new("bfio2", cfg, 16);
        j.record_arrival(0, 1, 0, 10.0, 5);
        let costs = j.record_route(0, 10.0, Some(1));
        costs.push((0, 1.5));
        costs.push((1, 0.5));
        j.record_fault(3, 1, &FaultKind::Stall(4.0));
        j.record_health(4, 1, 0, 1);
        j.record_lifecycle(5, 2, LC_ADD, 2, 2, 1.0);
        let _ = j.record_route(5, 3.0, None); // overflow
        j
    }

    #[test]
    fn ring_evicts_oldest_and_bounds_memory() {
        let mut ring = JournalRing::new(4);
        for i in 0..10u64 {
            ring.record(EV_ARRIVAL, i, i, 0, 0, 0.0);
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.cap(), 4);
        assert_eq!(ring.dropped(), 6);
        let rounds: Vec<u64> = ring.events().map(|e| e.round).collect();
        assert_eq!(rounds, vec![6, 7, 8, 9], "oldest evicted first");
        assert!(ring.buf.len() <= 4, "buffer never exceeds cap");
    }

    #[test]
    fn binary_round_trip_is_lossless() {
        let j = fixture();
        let bytes = j.to_binary();
        let j2 = Journal::from_binary(&bytes).unwrap();
        assert_eq!(bytes, j2.to_binary());
        assert_eq!(j2.config.router, "bfio2");
        assert_eq!(j2.ring.len(), j.ring.len());
        assert_eq!(j2.route_seq, 2);
        assert_eq!(j2.route_decisions(), vec![2, 0]);
        let evs: Vec<&JournalEvent> = j2.ring.events().collect();
        assert_eq!(evs[1].costs, vec![(0, 1.5), (1, 0.5)]);
    }

    #[test]
    fn jsonl_round_trip_matches_binary() {
        let j = fixture();
        let text = j.to_jsonl();
        assert!(text.lines().next().unwrap().contains("\"journal\":true"));
        let j2 = Journal::from_jsonl(&text).unwrap();
        assert_eq!(
            j.to_binary(),
            j2.to_binary(),
            "JSONL must convert losslessly back to the binary frame"
        );
    }

    #[test]
    fn load_sniffs_format_by_magic() {
        let j = fixture();
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let bin = dir.join(format!("bfio_journal_{pid}.bin"));
        let jsonl = dir.join(format!("bfio_journal_{pid}.jsonl"));
        j.save(&bin).unwrap();
        j.save(&jsonl).unwrap();
        let a = Journal::load(&bin).unwrap();
        let b = Journal::load(&jsonl).unwrap();
        assert_eq!(a.to_binary(), b.to_binary());
        let _ = std::fs::remove_file(&bin);
        let _ = std::fs::remove_file(&jsonl);
    }

    #[test]
    fn fault_codes_round_trip() {
        for kind in [FaultKind::Crash, FaultKind::Stall(3.0), FaultKind::Recover] {
            let (code, x) = fault_code(&kind);
            assert_eq!(fault_of(code, x), Some(kind));
        }
        assert_eq!(fault_of(9, 0.0), None);
    }

    #[test]
    fn summary_diff_tolerances() {
        let mut a = ResultSummary {
            router: "BF-IO-2L".into(),
            policy: "BF-IO".into(),
            rounds: 10,
            steps: 40,
            submitted: 20,
            completed: 20,
            total_tokens: 800.0,
            makespan_s: 12.0,
            clock_ratio: 1.0,
            energy_j: 9000.0,
            avg_imbalance: 0.1,
            tpot_s: 0.05,
            mean_queue_wait_s: 0.2,
            throughput_tps: 66.0,
            leftover_waiting: 0,
            slo_goodput: 1.0,
            crashes: 0,
            stalls: 0,
            recoveries: 0,
            requeued: 0,
            shed: 0,
            regret_decisions: 20,
            regret_audited: 20,
            regret_cumulative: 0.0,
            max_regret: 0.0,
            attributed_waste_j: 100.0,
            per_replica: Vec::new(),
        };
        let b = a.clone();
        assert!(a.diff(&b).is_empty());
        a.energy_j += a.energy_j * 1e-12; // inside 1e-9 relative
        assert!(a.diff(&b).is_empty());
        a.energy_j = b.energy_j + 1.0;
        a.completed = 19;
        let d = a.diff(&b);
        assert_eq!(d.len(), 2, "one int + one float mismatch: {d:?}");
        assert!(a.energy_per_token_j() > 0.0);
    }
}
