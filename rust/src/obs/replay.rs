//! Counterfactual replay: re-run a journaled fleet trajectory
//! ([`crate::obs::journal`]) — exactly, or under a what-if override.
//!
//! Two modes:
//!
//! * **pinned** (no overrides): every recorded routing decision is
//!   *forced* back onto the core while the wrapped router's internal
//!   state (WRR credits, power-of-d sample draws, the routing RNG
//!   stream) is still driven exactly as recorded.  Because the
//!   simulator is strictly deterministic, pinned replay must reproduce
//!   the recorded [`crate::fleet::FleetResult`] with integers exact and
//!   floats ≤ 1e-9 — `bfio replay --check` diffs the outcome against
//!   the journal's recorded [`ResultSummary`] and a non-empty diff is a
//!   determinism bug (or a corrupted journal).
//! * **counterfactual** (`--router` / `--no-faults` / `--speeds`;
//!   `--threads` alone stays pinned since parallel ≡ serial is exact):
//!   routing is re-decided live while the journaled arrivals, fault
//!   schedule (unless suppressed), and lifecycle actions stay fixed —
//!   "what would this exact bad afternoon have cost under `low`?".
//!   The trajectory-level regret of the recorded run is then
//!   `pinned − best counterfactual` on the metric of interest
//!   (energy/token primary), computed by
//!   [`crate::experiments::replay`].
//!
//! Faithfulness bounds: a journal whose ring evicted events
//! (`dropped > 0`) is refused — the prefix of the trajectory is gone.
//! A wedged run that a live controller hook sat out for its full
//! 10 000-round stall window is cut short after one wedged round here
//! (the journal records no events that would unwedge it, so the tail
//! is round-count padding, not dynamics).  Gateway-recorded journals
//! replay through the offline core: the arrival *schedule* is exact,
//! while gateway-side shed-on-retry corners are approximated by the
//! offline requeue rule.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::fault::FaultEvent;
use crate::fleet::{FleetCore, FleetFinished, FleetResult, FleetRouter, ReplicaView};
use crate::gateway::backend::{
    Backend, BackendStats, Completion, CompletionRequest, WorkerStatus,
};
use crate::obs::journal::{
    fault_of, Journal, JournalEvent, ResultSummary, EV_ARRIVAL, EV_FAULT,
    EV_LIFECYCLE, LC_ADD, LC_DRAIN, LC_REACTIVATE, LC_REMOVE,
};
use crate::obs::series::SeriesRing;
use crate::util::rng::Rng;
use crate::workload::Request;

/// A tier-1 router that forces the journal's recorded decisions while
/// still driving the wrapped router through every call — so the inner
/// router's state and the shared routing RNG stream evolve exactly as
/// in the recorded run, and `decision_cost` audits against the same
/// cost surface.
pub struct PinnedRouter {
    inner: Box<dyn FleetRouter>,
    /// Recorded decisions in sequence order: replica id + 1, 0 =
    /// overflow ([`Journal::route_decisions`]).
    decisions: Vec<u64>,
    cursor: usize,
    /// Decisions where the freshly computed pick disagreed with the
    /// recorded one and was overridden (must stay 0 on a true pinned
    /// replay — nonzero means the trajectory diverged upstream).
    forced: Arc<AtomicU64>,
    /// Route calls beyond the recorded decision list (ditto).
    extra: Arc<AtomicU64>,
}

impl PinnedRouter {
    pub fn new(
        inner: Box<dyn FleetRouter>,
        decisions: Vec<u64>,
    ) -> (PinnedRouter, Arc<AtomicU64>, Arc<AtomicU64>) {
        let forced = Arc::new(AtomicU64::new(0));
        let extra = Arc::new(AtomicU64::new(0));
        let router = PinnedRouter {
            inner,
            decisions,
            cursor: 0,
            forced: Arc::clone(&forced),
            extra: Arc::clone(&extra),
        };
        (router, forced, extra)
    }
}

impl FleetRouter for PinnedRouter {
    /// The wrapped router's display name, so a pinned replay's
    /// [`FleetResult::router`] matches the recorded label.
    fn name(&self) -> String {
        self.inner.name()
    }

    fn route(
        &mut self,
        prefill: f64,
        replicas: &[ReplicaView],
        rng: &mut Rng,
    ) -> Option<usize> {
        // Drive the inner router first — its credits/samples/RNG draws
        // must consume the stream exactly as recorded.
        let fresh = self.inner.route(prefill, replicas, rng);
        let rec = self.decisions.get(self.cursor).copied();
        self.cursor += 1;
        match rec {
            // Recorded overflow: no replica accepted.  Returning `None`
            // sends the core to its least-outstanding fallback, which
            // (state being identical) also finds nothing — the request
            // overflows exactly as recorded.
            Some(0) => None,
            Some(code) => {
                let id = (code - 1) as usize;
                if fresh != Some(id) {
                    self.forced.fetch_add(1, Ordering::Relaxed);
                }
                Some(id)
            }
            None => {
                self.extra.fetch_add(1, Ordering::Relaxed);
                fresh
            }
        }
    }

    fn decision_cost(&self, prefill: f64, v: &ReplicaView) -> Option<f64> {
        self.inner.decision_cost(prefill, v)
    }
}

/// What-if overrides for a replay.  All `None`/`false` (the default) ⇒
/// pinned mode.  `threads` alone keeps the replay pinned: round
/// parallelism is locked bit-exact by the `fleet_parity` suite, so it
/// is a wall-clock knob, not a counterfactual.
#[derive(Clone, Debug, Default)]
pub struct ReplayOptions {
    /// Re-decide routing under this router spec (`wrr | low | powd:<d>
    /// | bfio2 | bfio2h`) instead of forcing recorded decisions.
    pub router: Option<String>,
    /// Override round-execution threads.
    pub threads: Option<usize>,
    /// Suppress the journaled fault events (the "clean-room" baseline a
    /// faulted run is compared against).
    pub no_faults: bool,
    /// Override replica speed factors (must match the recorded initial
    /// fleet size — lifecycle/fault events reference replica ids).
    pub speeds: Option<Vec<f64>>,
}

impl ReplayOptions {
    /// True when the replay will force recorded decisions (bit-exact
    /// reproduction) rather than re-deciding.
    pub fn is_pinned(&self) -> bool {
        self.router.is_none() && !self.no_faults && self.speeds.is_none()
    }
}

/// Outcome of one replay run.
pub struct ReplayOutcome {
    pub result: FleetResult,
    /// Whether recorded decisions were forced (pinned) or re-decided.
    pub pinned: bool,
    /// Pinned-mode divergence diagnostics (both must be 0 on a healthy
    /// pinned replay; always 0 in counterfactual mode).
    pub forced: u64,
    pub extra: u64,
    /// The replayed run's windowed time-series ring — what
    /// `bfio replay --dash` serves through the `/v0/dash` dashboard.
    pub series: SeriesRing,
}

impl ReplayOutcome {
    /// The replay's outcome in journal-comparable form.
    pub fn summary(&self) -> ResultSummary {
        ResultSummary::from_result(&self.result)
    }
}

/// Apply every journal event due at the core's current round, in
/// recorded order.  Fault events are applied in their recorded batches
/// (all due faults, then one crash-loss requeue pass), mirroring the
/// live driver's `apply_faults`.
fn apply_due(
    core: &mut FleetCore<u32, ()>,
    evs: &[JournalEvent],
    cursor: &mut usize,
    id_to_idx: &HashMap<u64, u32>,
) -> Result<()> {
    while *cursor < evs.len() && evs[*cursor].round <= core.round() {
        let ev = &evs[*cursor];
        *cursor += 1;
        match ev.kind {
            EV_ARRIVAL => {
                if let Some(&idx) = id_to_idx.get(&ev.a) {
                    core.submit(ev.x, ev.c, idx);
                }
            }
            EV_LIFECYCLE => match ev.b as u8 {
                LC_ADD => {
                    let g = (ev.c >> 32) as usize;
                    let b = (ev.c & 0xffff_ffff) as usize;
                    let _ = core.add_replica_shaped(ev.x, g, b);
                }
                LC_REACTIVATE => {
                    core.reactivate_replica(ev.a as usize);
                }
                LC_DRAIN => core.drain_replica(ev.a as usize, false),
                LC_REMOVE => core.drain_replica(ev.a as usize, true),
                op => bail!("journal: unknown lifecycle op {op}"),
            },
            EV_FAULT => {
                apply_fault_ev(core, ev)?;
                // One recorded batch = every fault applied at the same
                // round boundary; the journal keeps them adjacent, and
                // the round gate separates batches applied at different
                // rounds.
                while *cursor < evs.len()
                    && evs[*cursor].kind == EV_FAULT
                    && evs[*cursor].round <= core.round()
                {
                    let next = &evs[*cursor];
                    *cursor += 1;
                    apply_fault_ev(core, next)?;
                }
                // Requeue what the batch's crashes lost: first loss
                // resubmits at the current round, repeat loss is
                // already shed and tallied by `drain_lost` — the live
                // drivers' rule exactly.
                if core.has_lost() {
                    let round = core.round();
                    for (id, prefill, _o, (), requeue) in core.drain_lost() {
                        if requeue {
                            if let Some(&idx) = id_to_idx.get(&id) {
                                core.resubmit(prefill, round, idx);
                            }
                        }
                    }
                }
            }
            kind => bail!("journal: unexpected event kind {kind} in replay walk"),
        }
    }
    Ok(())
}

fn apply_fault_ev(core: &mut FleetCore<u32, ()>, ev: &JournalEvent) -> Result<()> {
    let kind = fault_of(ev.b, ev.x)
        .ok_or_else(|| anyhow!("journal: unknown fault code {}", ev.b))?;
    core.apply_fault(&FaultEvent { round: ev.round, replica: ev.a as usize, kind });
    Ok(())
}

/// Re-run a journaled trajectory — pinned (exact reproduction) or
/// counterfactual (overridden routing over the identical arrival /
/// fault / lifecycle schedule).  See the module docs for the
/// faithfulness contract.
pub fn replay_journal(journal: &Journal, opts: &ReplayOptions) -> Result<ReplayOutcome> {
    if journal.ring.dropped() > 0 {
        bail!(
            "journal dropped {} events (ring cap {}): the trajectory is not \
             reconstructable — record with a larger --journal-cap",
            journal.ring.dropped(),
            journal.ring.cap()
        );
    }
    let mut cfg = journal.config.fleet.clone();
    if let Some(t) = opts.threads {
        cfg.threads = t;
    }
    if let Some(speeds) = &opts.speeds {
        if speeds.len() != cfg.speeds.len() {
            bail!(
                "--speeds must list {} factors (the recorded initial fleet), got {}",
                cfg.speeds.len(),
                speeds.len()
            );
        }
        cfg.speeds = speeds.clone();
    }
    let pinned = opts.is_pinned();
    let router_spec = opts
        .router
        .clone()
        .unwrap_or_else(|| journal.config.router.clone());
    let base = cfg
        .router(&router_spec)
        .ok_or_else(|| anyhow!("unknown fleet router {router_spec:?}"))?;
    let (router, forced, extra): (Box<dyn FleetRouter>, Arc<AtomicU64>, Arc<AtomicU64>) =
        if pinned {
            let (p, f, e) = PinnedRouter::new(base, journal.route_decisions());
            (Box::new(p), f, e)
        } else {
            (base, Arc::new(AtomicU64::new(0)), Arc::new(AtomicU64::new(0)))
        };
    let router_label = router.name();
    let policy_label = crate::policies::by_name(&cfg.policy)
        .ok_or_else(|| anyhow!("unknown policy {:?}", cfg.policy))?
        .name();

    // Reconstruct the trace and the ordered walk list (arrivals,
    // lifecycle, faults); routing decisions ride in the PinnedRouter
    // and health transitions are re-derived by the core's own monitor.
    let mut trace: Vec<Request> = Vec::new();
    let mut id_to_idx: HashMap<u64, u32> = HashMap::new();
    let mut evs: Vec<JournalEvent> = Vec::new();
    for ev in journal.ring.events() {
        match ev.kind {
            EV_ARRIVAL => {
                id_to_idx.insert(ev.a, trace.len() as u32);
                trace.push(Request {
                    id: ev.a,
                    arrival_step: ev.c,
                    prefill: ev.x,
                    decode_len: ev.b.max(1),
                });
                evs.push(ev.clone());
            }
            EV_LIFECYCLE => evs.push(ev.clone()),
            EV_FAULT if !opts.no_faults => evs.push(ev.clone()),
            _ => {}
        }
    }

    let mut core: FleetCore<u32, ()> = FleetCore::new(cfg.clone(), router)?;
    let mut cursor = 0usize;
    let mut out: Vec<FleetFinished<()>> = Vec::new();

    loop {
        apply_due(&mut core, &evs, &mut cursor, &id_to_idx)?;

        // Fleet-wide idle gap: jump to the next journaled event (the
        // walk list is chronological, so its head is the global next).
        if core.is_idle() {
            let Some(next) = evs.get(cursor).map(|e| e.round) else { break };
            if cfg.max_rounds > 0 && next >= cfg.max_rounds {
                break;
            }
            if next > core.round() {
                core.skip_to_round(next);
                apply_due(&mut core, &evs, &mut cursor, &id_to_idx)?;
            }
        }

        if core.is_idle() && cursor >= evs.len() {
            break; // drained
        }

        let stepped = core.run_round(
            &|_, idx| {
                let r = &trace[idx as usize];
                (r.id, r.decode_len, ())
            },
            &mut out,
        );

        if cfg.max_rounds > 0 && core.round() >= cfg.max_rounds {
            break;
        }
        // Wedged with nothing left in the journal to unwedge it: stop
        // (the hookless offline driver's rule; see the module docs for
        // the hooked-run corner).
        if stepped == 0 && !core.is_idle() && !core.has_accepting() && cursor >= evs.len() {
            break;
        }
    }

    let rounds = core.round();
    let submitted = core.submitted();
    let overflow = core.overflow_len();
    let counters = core.fault_counters();
    let drained = core.is_idle() && cursor >= evs.len();
    let regret = core.regret().clone();
    let attributed_waste_j = core.attributed_waste_fleet_j();
    let series = core.series().clone();
    let per_replica = core.into_results();
    let mut res = crate::fleet::aggregate(
        router_label,
        policy_label,
        rounds,
        submitted,
        per_replica,
        counters,
    );
    res.regret = regret;
    res.attributed_waste_j = attributed_waste_j;
    res.leftover_waiting += overflow;
    debug_assert!(
        !drained || res.completed + res.shed == res.submitted,
        "replay conservation: completed {} + shed {} != submitted {}",
        res.completed,
        res.shed,
        res.submitted
    );
    Ok(ReplayOutcome {
        result: res,
        pinned,
        forced: forced.load(Ordering::Relaxed),
        extra: extra.load(Ordering::Relaxed),
        series,
    })
}

/// A read-only gateway backend over a replayed journal: serves the
/// replay's time-series ring through `GET /v0/series` + the live
/// `GET /v0/dash` dashboard, and the journal itself through
/// `GET /v0/journal` — postmortems get the dashboard view offline
/// (`bfio replay --dash`).
pub struct ReplayDashBackend {
    label: String,
    policy: String,
    series: SeriesRing,
    jsonl: String,
}

impl ReplayDashBackend {
    pub fn new(
        label: String,
        policy: String,
        series: SeriesRing,
        jsonl: String,
    ) -> ReplayDashBackend {
        ReplayDashBackend { label, policy, series, jsonl }
    }
}

impl Backend for ReplayDashBackend {
    fn name(&self) -> String {
        format!("replay/{}", self.label)
    }

    fn complete(&self, _req: CompletionRequest) -> Result<Completion> {
        bail!("replay dashboard is read-only: the journaled run already executed")
    }

    fn workers(&self) -> Vec<WorkerStatus> {
        Vec::new()
    }

    fn stats(&self) -> BackendStats {
        BackendStats { policy: self.policy.clone(), ..BackendStats::default() }
    }

    fn series_json(&self, last: usize) -> Option<String> {
        Some(self.series.to_json(last))
    }

    fn journal_jsonl(&self) -> Option<String> {
        Some(self.jsonl.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::router::LeastOutstanding;

    fn view(id: usize, load_sum: f64) -> ReplicaView {
        ReplicaView {
            id,
            speed: 1.0,
            accepting: true,
            workers: 2,
            slots: 4,
            free_slots: 4,
            active: 0,
            queue_depth: 0,
            load_sum,
            max_load: load_sum / 2.0,
            min_load: load_sum / 2.0,
            queued_prefill: 0.0,
            completion_horizon: 0,
            clock_s: 0.0,
            penalty: 1.0,
        }
    }

    #[test]
    fn pinned_router_forces_recorded_decisions() {
        // Recorded: r1, r0, overflow.  The inner router (low) would
        // pick r1 every time — decisions 2 and 3 are forced.
        let (mut r, forced, extra) =
            PinnedRouter::new(Box::new(LeastOutstanding), vec![2, 1, 0]);
        let views = vec![view(0, 100.0), view(1, 10.0)];
        let mut rng = Rng::new(1);
        assert_eq!(r.route(5.0, &views, &mut rng), Some(1));
        assert_eq!(r.route(5.0, &views, &mut rng), Some(0));
        assert_eq!(r.route(5.0, &views, &mut rng), None, "recorded overflow");
        assert_eq!(forced.load(Ordering::Relaxed), 2);
        assert_eq!(extra.load(Ordering::Relaxed), 0);
        // Past the recorded list: fall through to the live pick.
        assert_eq!(r.route(5.0, &views, &mut rng), Some(1));
        assert_eq!(extra.load(Ordering::Relaxed), 1);
        assert_eq!(r.name(), "LeastOutstanding");
    }

    #[test]
    fn replay_options_pinned_rules() {
        assert!(ReplayOptions::default().is_pinned());
        let t = ReplayOptions { threads: Some(8), ..ReplayOptions::default() };
        assert!(t.is_pinned(), "threads alone stays pinned (parity is exact)");
        let r = ReplayOptions { router: Some("low".into()), ..ReplayOptions::default() };
        assert!(!r.is_pinned());
        let f = ReplayOptions { no_faults: true, ..ReplayOptions::default() };
        assert!(!f.is_pinned());
    }

    #[test]
    fn dash_backend_is_read_only() {
        let b = ReplayDashBackend::new(
            "BF-IO-2L".into(),
            "BF-IO".into(),
            SeriesRing::new(8, 16),
            "{\"journal\":true}\n".into(),
        );
        assert!(b.name().starts_with("replay/"));
        let req = CompletionRequest { id: 1, prompt_tokens: vec![1, 2], max_tokens: 4 };
        assert!(b.complete(req).is_err());
        assert!(b.series_json(8).is_some());
        assert_eq!(b.journal_jsonl().unwrap(), "{\"journal\":true}\n");
        assert!(b.workers().is_empty());
    }
}
