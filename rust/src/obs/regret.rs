//! Online routing-regret audit: how good was each tier-1 decision
//! versus the counterfactual best placement, by the router's own
//! marginal Eq. 19 cost model?
//!
//! All five tier-1 routers expose a cost surface: the marginal-cost
//! routers (`low`, `bfio2`, `bfio2h`) evaluate Eq. 19 per candidate,
//! WRR exposes its negated smoothed credits, and power-of-d scores its
//! sampled subset (candidates it never drew return `None` and are
//! excluded from "best").  The audit replays that cost over every
//! accepting replica *after* the pick and records
//! `chosen_cost − best_cost` into a [`QuantileSketch`] plus counters —
//! exact routers therefore show regret ≡ 0 on any fleet, the audit's
//! built-in self-check.  Cumulative regret surfacing next to the health
//! penalties tells an operator when a router is *systematically*
//! mis-placing (e.g. stale views or a penalty pinned by a flapping
//! replica).
//!
//! Observability-only: the audit reads costs through
//! [`crate::fleet::FleetRouter::decision_cost`] (`&self`, no router
//! state mutation) and never alters the pick, so routing behavior and
//! the parity suites are untouched.

use crate::obs::attrib::Kahan;
use crate::obs::QuantileSketch;

/// Regret at or below this is recorded as exactly 0.0.  Matches the
/// tie-break epsilon of the routers' own argmin scan, so a pick that
/// tied within epsilon (and was broken by the secondary key) does not
/// register phantom regret.
pub const REGRET_EPS: f64 = 1e-12;

/// Cumulative routing-regret audit for one fleet core.
#[derive(Clone, Debug)]
pub struct RegretAudit {
    /// Every routing decision seen (audited or not).
    pub decisions: u64,
    /// Decisions where the router exposed a marginal cost to audit.
    pub audited: u64,
    /// Largest single-decision regret observed.
    pub max_regret: f64,
    /// Per-decision regret distribution (seconds of marginal Eq. 19
    /// cost); zero-regret decisions land in the sketch's zero bucket.
    pub sketch: QuantileSketch,
    cumulative: Kahan,
}

impl Default for RegretAudit {
    fn default() -> RegretAudit {
        RegretAudit {
            decisions: 0,
            audited: 0,
            max_regret: 0.0,
            sketch: QuantileSketch::default(),
            cumulative: Kahan::default(),
        }
    }
}

impl RegretAudit {
    pub fn new() -> RegretAudit {
        RegretAudit::default()
    }

    /// A decision by a router with no auditable cost model (WRR,
    /// power-of-d): counted, not measured.
    pub fn note_unaudited(&mut self) {
        self.decisions += 1;
    }

    /// Record one audited decision; returns the recorded regret.
    pub fn record(&mut self, chosen_cost: f64, best_cost: f64) -> f64 {
        self.decisions += 1;
        self.audited += 1;
        let mut r = (chosen_cost - best_cost).max(0.0);
        if r <= REGRET_EPS {
            r = 0.0;
        }
        self.cumulative.add(r);
        if r > self.max_regret {
            self.max_regret = r;
        }
        self.sketch.insert(r);
        r
    }

    /// Total regret-seconds accumulated (compensated sum).
    pub fn cumulative(&self) -> f64 {
        self.cumulative.value()
    }

    /// Mean regret per audited decision.
    pub fn mean(&self) -> f64 {
        if self.audited == 0 {
            0.0
        } else {
            self.cumulative() / self.audited as f64
        }
    }

    /// In-place copy for the gateway's zero-steady-state-alloc publish
    /// path (reuses the destination sketch's bucket allocation).
    pub fn copy_from(&mut self, src: &RegretAudit) {
        self.decisions = src.decisions;
        self.audited = src.audited;
        self.max_regret = src.max_regret;
        self.cumulative = src.cumulative;
        self.sketch.copy_from(&src.sketch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_router_shows_zero_regret() {
        let mut a = RegretAudit::new();
        for _ in 0..1000 {
            // An exact argmin pick: chosen == best (and fp ties within
            // the router's epsilon floor to exactly zero).
            assert_eq!(a.record(0.5, 0.5), 0.0);
            assert_eq!(a.record(0.5 + 0.9e-12, 0.5), 0.0);
        }
        assert_eq!(a.decisions, 2000);
        assert_eq!(a.audited, 2000);
        assert_eq!(a.cumulative(), 0.0);
        assert_eq!(a.max_regret, 0.0);
        assert_eq!(a.mean(), 0.0);
        assert_eq!(a.sketch.quantile(1.0), Some(0.0));
    }

    #[test]
    fn regret_accumulates_and_copies() {
        let mut a = RegretAudit::new();
        a.note_unaudited();
        assert_eq!(a.record(1.5, 1.0), 0.5);
        assert_eq!(a.record(2.0, 1.75), 0.25);
        // Negative differences (best filter wider than the pick set)
        // clamp to zero rather than crediting the router.
        assert_eq!(a.record(1.0, 2.0), 0.0);
        assert_eq!(a.decisions, 4);
        assert_eq!(a.audited, 3);
        assert!((a.cumulative() - 0.75).abs() < 1e-15);
        assert!((a.max_regret - 0.5).abs() < 1e-15);
        assert!((a.mean() - 0.25).abs() < 1e-15);
        let mut b = RegretAudit::new();
        b.copy_from(&a);
        assert_eq!(b.decisions, a.decisions);
        assert_eq!(b.audited, a.audited);
        assert_eq!(b.cumulative(), a.cumulative());
        assert_eq!(b.sketch.count(), a.sketch.count());
    }
}
