//! DDSketch-style streaming quantile sketch with relative-error
//! guarantees (Masson, Rim & Lee, VLDB 2019 — reimplemented from the
//! paper's bucket rule; no crate dependency).
//!
//! Values are mapped to logarithmic buckets `key = ⌈ln x / ln γ⌉` with
//! `γ = (1+α)/(1−α)`; any reported quantile is then within relative
//! error `α` of the exact sample quantile.  Buckets are a contiguous
//! `Vec<u64>` with a sliding key offset, so memory is **bounded by the
//! dynamic range** (for `α = 0.01` and the clamped range
//! `[1e−9, 1e12]`, at most ~2400 buckets ≈ 19 KiB) regardless of how
//! many samples are inserted — unlike the store-every-sample
//! `Vec<f64>`-and-sort path it replaces.
//!
//! Sketches with the same `α` merge by bucket-wise addition
//! ([`QuantileSketch::merge`]), which is exact: merging then querying
//! equals querying the union, so per-replica sketches fold into fleet
//! totals and Prometheus histogram families stay aggregatable.

/// Default relative accuracy: quantile estimates within ±1%.
pub const DEFAULT_ALPHA: f64 = 0.01;

/// Positive values below this are counted in the zero bucket; above
/// [`CLAMP_HI`] they clamp to the top bucket.  Bounds the key range.
const CLAMP_LO: f64 = 1e-9;
const CLAMP_HI: f64 = 1e12;

/// A mergeable streaming quantile sketch with relative error `alpha`.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantileSketch {
    alpha: f64,
    gamma: f64,
    ln_gamma: f64,
    /// `bins[i]` counts samples with bucket key `offset + i`.
    bins: Vec<u64>,
    offset: i32,
    /// Samples ≤ 0 (or below [`CLAMP_LO`]).
    zero_count: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for QuantileSketch {
    fn default() -> QuantileSketch {
        QuantileSketch::new(DEFAULT_ALPHA)
    }
}

impl QuantileSketch {
    /// New sketch with relative accuracy `alpha` (0 < alpha < 1).
    pub fn new(alpha: f64) -> QuantileSketch {
        assert!(alpha > 0.0 && alpha < 1.0, "alpha must be in (0, 1)");
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        QuantileSketch {
            alpha,
            gamma,
            ln_gamma: gamma.ln(),
            bins: Vec::new(),
            offset: 0,
            zero_count: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// The configured relative accuracy.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> Option<f64> {
        (self.count > 0).then_some(self.min)
    }

    pub fn max(&self) -> Option<f64> {
        (self.count > 0).then_some(self.max)
    }

    fn key_of(&self, x: f64) -> i32 {
        let x = x.clamp(CLAMP_LO, CLAMP_HI);
        (x.ln() / self.ln_gamma).ceil() as i32
    }

    /// Midpoint estimate for bucket `key`: `2γ^k / (γ + 1)`, within
    /// relative error `alpha` of every sample in the bucket.
    fn value_of(&self, key: i32) -> f64 {
        2.0 * (key as f64 * self.ln_gamma).exp() / (self.gamma + 1.0)
    }

    fn bump(&mut self, key: i32) {
        if self.bins.is_empty() {
            self.offset = key;
            self.bins.push(1);
            return;
        }
        if key < self.offset {
            let grow = (self.offset - key) as usize;
            self.bins.resize(self.bins.len() + grow, 0);
            self.bins.rotate_right(grow);
            self.offset = key;
            self.bins[0] += 1;
        } else {
            let idx = (key - self.offset) as usize;
            if idx >= self.bins.len() {
                self.bins.resize(idx + 1, 0);
            }
            self.bins[idx] += 1;
        }
    }

    /// Insert one sample.  Non-finite values are ignored; values ≤ 0
    /// land in the zero bucket (and report as 0.0 in quantiles).
    pub fn insert(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        self.count += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        if x < CLAMP_LO {
            self.zero_count += 1;
            return;
        }
        let key = self.key_of(x);
        self.bump(key);
    }

    /// Quantile estimate for `q` in [0, 1]; `None` when empty.  The
    /// estimate is within relative error `alpha` of the exact sample
    /// quantile (exactly 0.0 for samples in the zero bucket), and the
    /// extremes are exact: `q = 0` returns `min`, `q = 1` returns `max`.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        assert!((0.0..=1.0).contains(&q), "quantile q must be in [0, 1]");
        if self.count == 0 {
            return None;
        }
        if q <= 0.0 {
            return Some(self.min);
        }
        if q >= 1.0 {
            return Some(self.max);
        }
        let rank = (q * (self.count - 1) as f64) as u64; // floor
        if rank < self.zero_count {
            return Some(0.0);
        }
        let mut cum = self.zero_count;
        for (i, &n) in self.bins.iter().enumerate() {
            cum += n;
            if cum > rank {
                return Some(self.value_of(self.offset + i as i32));
            }
        }
        Some(self.max) // fp safety net; unreachable when counts agree
    }

    /// Number of samples ≤ `bound` (within the bucket resolution: the
    /// boundary bucket is attributed by its upper edge, so the answer
    /// is exact for counts and within relative error `alpha` in the
    /// bound).  Used to render cumulative Prometheus histogram buckets.
    pub fn count_le(&self, bound: f64) -> u64 {
        if bound.is_nan() {
            return 0;
        }
        if bound < 0.0 {
            return 0;
        }
        if bound.is_infinite() {
            return self.count;
        }
        let mut cum = self.zero_count;
        if bound < CLAMP_LO {
            return cum;
        }
        let key_hi = self.key_of(bound);
        for (i, &n) in self.bins.iter().enumerate() {
            if self.offset + i as i32 > key_hi {
                break;
            }
            cum += n;
        }
        cum
    }

    /// Fold `other` into `self` (bucket-wise; requires equal `alpha`).
    pub fn merge(&mut self, other: &QuantileSketch) {
        assert!(
            (self.alpha - other.alpha).abs() < 1e-12,
            "cannot merge sketches with different alpha ({} vs {})",
            self.alpha,
            other.alpha
        );
        if other.count == 0 {
            return;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.zero_count += other.zero_count;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
        if other.bins.is_empty() {
            return;
        }
        if self.bins.is_empty() {
            self.offset = other.offset;
            self.bins.extend_from_slice(&other.bins);
            return;
        }
        // Grow self's range to cover other's, then add bucket-wise.
        if other.offset < self.offset {
            let grow = (self.offset - other.offset) as usize;
            self.bins.resize(self.bins.len() + grow, 0);
            self.bins.rotate_right(grow);
            self.offset = other.offset;
        }
        let need = (other.offset - self.offset) as usize + other.bins.len();
        if need > self.bins.len() {
            self.bins.resize(need, 0);
        }
        let base = (other.offset - self.offset) as usize;
        for (i, &n) in other.bins.iter().enumerate() {
            self.bins[base + i] += n;
        }
    }

    /// Reset to empty, retaining bucket capacity.
    pub fn clear(&mut self) {
        self.bins.clear();
        self.offset = 0;
        self.zero_count = 0;
        self.count = 0;
        self.sum = 0.0;
        self.min = f64::INFINITY;
        self.max = f64::NEG_INFINITY;
    }

    /// Copy `src` into `self`, reusing this sketch's allocations.
    pub fn copy_from(&mut self, src: &QuantileSketch) {
        self.alpha = src.alpha;
        self.gamma = src.gamma;
        self.ln_gamma = src.ln_gamma;
        self.bins.clear();
        self.bins.extend_from_slice(&src.bins);
        self.offset = src.offset;
        self.zero_count = src.zero_count;
        self.count = src.count;
        self.sum = src.sum;
        self.min = src.min;
        self.max = src.max;
    }
}

/// The default `le` bucket ladder for seconds-scale latency histograms
/// on `/metrics` (the implicit `+Inf` bucket is appended by the
/// renderer).  Fixed per family so scrapes stay aggregatable across
/// replicas and over time.
pub fn seconds_buckets() -> &'static [f64] {
    &[
        0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
        30.0,
    ]
}

/// Bucket ladder for token-scale quantities (per-step imbalance): decade
/// steps covering one stray token up to full-fleet KV residency.
pub fn token_buckets() -> &'static [f64] {
    &[1.0, 10.0, 100.0, 1e3, 1e4, 1e5, 1e6, 1e7]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_sketch() {
        let s = QuantileSketch::default();
        assert!(s.is_empty());
        assert_eq!(s.quantile(0.5), None);
        assert_eq!(s.min(), None);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.count_le(f64::INFINITY), 0);
    }

    #[test]
    fn single_value_everywhere() {
        let mut s = QuantileSketch::default();
        s.insert(0.125);
        assert_eq!(s.count(), 1);
        assert_eq!(s.quantile(0.0), Some(0.125), "q=0 is exact min");
        assert_eq!(s.quantile(1.0), Some(0.125), "q=1 is exact max");
        let mid = s.quantile(0.5).unwrap();
        assert!((mid - 0.125).abs() / 0.125 <= DEFAULT_ALPHA);
    }

    #[test]
    fn relative_error_bound_on_uniform_grid() {
        let mut s = QuantileSketch::new(0.02);
        let xs: Vec<f64> = (1..=10_000).map(|i| i as f64 * 1e-3).collect();
        for &x in &xs {
            s.insert(x);
        }
        for &q in &[0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999] {
            let exact = crate::util::stats::percentile(&xs, q * 100.0);
            let est = s.quantile(q).unwrap();
            assert!(
                (est - exact).abs() / exact <= 0.02 + 1e-9,
                "q={q}: est {est} vs exact {exact}"
            );
        }
        assert_eq!(s.count(), 10_000);
        assert!((s.sum() - xs.iter().sum::<f64>()).abs() < 1e-6);
    }

    #[test]
    fn zero_and_negative_values() {
        let mut s = QuantileSketch::default();
        s.insert(0.0);
        s.insert(-5.0);
        s.insert(1.0);
        assert_eq!(s.count(), 3);
        assert_eq!(s.quantile(0.0), Some(-5.0), "min is exact");
        assert_eq!(s.quantile(0.4), Some(0.0), "zero bucket reports 0");
        assert_eq!(s.count_le(0.5), 2);
        assert_eq!(s.count_le(2.0), 3);
        s.insert(f64::NAN); // ignored
        assert_eq!(s.count(), 3);
    }

    #[test]
    fn merge_equals_union() {
        let mut a = QuantileSketch::default();
        let mut b = QuantileSketch::default();
        let mut all = QuantileSketch::default();
        for i in 1..=500 {
            let x = (i as f64).powi(2) * 1e-4;
            a.insert(x);
            all.insert(x);
        }
        for i in 1..=300 {
            let x = 5.0 / i as f64;
            b.insert(x);
            all.insert(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        for &q in &[0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), all.quantile(q), "merge is exact at q={q}");
        }
        for &le in seconds_buckets() {
            assert_eq!(a.count_le(le), all.count_le(le));
        }
    }

    #[test]
    fn count_le_is_monotone_and_caps_at_count() {
        let mut s = QuantileSketch::default();
        for i in 1..=1000u64 {
            s.insert(i as f64 * 7e-4);
        }
        let mut prev = 0;
        for &le in seconds_buckets() {
            let c = s.count_le(le);
            assert!(c >= prev, "cumulative buckets must not decrease");
            prev = c;
        }
        assert_eq!(s.count_le(f64::INFINITY), 1000);
    }

    #[test]
    fn clear_retains_capacity_and_copy_from_roundtrips() {
        let mut s = QuantileSketch::default();
        for i in 1..=100 {
            s.insert(i as f64);
        }
        let mut t = QuantileSketch::default();
        t.copy_from(&s);
        assert_eq!(t, s);
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.quantile(0.5), None);
        s.insert(2.0);
        assert_eq!(s.count(), 1);
    }
}
