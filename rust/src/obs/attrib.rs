//! Per-barrier-step straggler attribution: the "who gated it" ledger.
//!
//! Every barrier step is gated by its argmax-load worker (Eq. 19): the
//! step runs for `(C + t·max_g L_g)/f_r` no matter what the other
//! workers hold, so the Theorem-4 `idle + correction` joules the
//! non-gating workers burn waiting are *caused* by the gate.  The
//! [`GateLedger`] charges each step's waste to that worker, keeps
//! per-worker gate counts, and folds the charge back onto the request
//! most recently admitted to the gating worker — so a tier-1/tier-2
//! *placement* decision can be blamed for downstream waste, not just a
//! worker.
//!
//! The ledger is observability-only.  It reads energy-accumulator
//! deltas around each step and never feeds anything back into
//! virtual-time state, so the `fleet_parity`/`engine_parity` suites
//! are byte-identical with it enabled.
//!
//! Conservation is exact by construction: the charged per-step deltas
//! telescope to the accumulator totals, and both the per-worker
//! buckets and the grand total use Neumaier-compensated summation
//! ([`Kahan`]), so the fleet identity
//! `Σ_replicas attributed == Σ_replicas (idle + correction)` holds to
//! ≤1e-9 even over millions of steps (naive summation drifts by
//! ~n·eps·total and would breach the bound at realistic scale).

/// Neumaier-compensated accumulator.  `value()` is within ~1 ulp of
/// the true sum regardless of how many deltas were folded in — the
/// property the conservation identity leans on.
#[derive(Clone, Copy, Debug, Default)]
pub struct Kahan {
    sum: f64,
    c: f64,
}

impl Kahan {
    pub fn add(&mut self, x: f64) {
        let t = self.sum + x;
        if self.sum.abs() >= x.abs() {
            self.c += (self.sum - t) + x;
        } else {
            self.c += (x - t) + self.sum;
        }
        self.sum = t;
    }

    pub fn value(&self) -> f64 {
        self.sum + self.c
    }
}

/// Sentinel for "no request admitted on this worker yet".
pub const NO_REQUEST: u64 = u64::MAX;

/// Default size of the per-replica request-blame table.
pub const DEFAULT_BLAME_CAP: usize = 64;

/// One blamed request: the waste downstream of a placement decision.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Blame {
    pub request_id: u64,
    /// Idle + correction joules of the steps this request's worker
    /// gated while it was the most recent admission there.
    pub waste_j: f64,
    /// How many barrier steps it gated.
    pub gates: u64,
}

/// Slot-owned straggler-attribution ledger for one replica.
///
/// Lives next to the replica's engine and recorder, is touched only by
/// the thread stepping that replica (the [`crate::obs::Tracer`]
/// ownership pattern), and allocates nothing after construction: the
/// blame table is bounded by `blame_cap` with evict-min-waste
/// replacement, so it retains the worst offenders.
#[derive(Clone, Debug)]
pub struct GateLedger {
    gate_counts: Vec<u64>,
    waste: Vec<Kahan>,
    last_admitted: Vec<u64>,
    blame: Vec<Blame>,
    blame_cap: usize,
    gates: u64,
    total: Kahan,
}

impl GateLedger {
    pub fn new(workers: usize, blame_cap: usize) -> GateLedger {
        GateLedger {
            gate_counts: vec![0; workers],
            waste: vec![Kahan::default(); workers],
            last_admitted: vec![NO_REQUEST; workers],
            blame: Vec::with_capacity(blame_cap),
            blame_cap,
            gates: 0,
            total: Kahan::default(),
        }
    }

    /// Remember the most recent admission per worker; a later gate on
    /// that worker is blamed on this request's placement.
    pub fn note_admit(&mut self, worker: usize, request_id: u64) {
        if let Some(slot) = self.last_admitted.get_mut(worker) {
            *slot = request_id;
        }
    }

    /// Charge one barrier step's `idle + correction` delta to the
    /// gating worker (and to the request last placed on it).
    pub fn charge(&mut self, worker: usize, waste_j: f64) {
        let Some(count) = self.gate_counts.get_mut(worker) else {
            return;
        };
        *count += 1;
        self.gates += 1;
        self.waste[worker].add(waste_j);
        self.total.add(waste_j);
        let id = self.last_admitted[worker];
        if id != NO_REQUEST {
            self.blame_request(id, waste_j);
        }
    }

    fn blame_request(&mut self, request_id: u64, waste_j: f64) {
        if let Some(e) =
            self.blame.iter_mut().find(|e| e.request_id == request_id)
        {
            e.waste_j += waste_j;
            e.gates += 1;
            return;
        }
        let entry = Blame { request_id, waste_j, gates: 1 };
        if self.blame.len() < self.blame_cap {
            self.blame.push(entry);
            return;
        }
        // Full: replace the least-wasteful entry iff the newcomer
        // out-wastes it — the table keeps the worst offenders.
        if let Some((i, min)) = self
            .blame
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.waste_j.total_cmp(&b.1.waste_j))
        {
            if min.waste_j < waste_j {
                self.blame[i] = entry;
            }
        }
    }

    /// Per-worker gate counts (how often each worker was the argmax).
    pub fn gate_counts(&self) -> &[u64] {
        &self.gate_counts
    }

    /// Total gates charged (== barrier steps attributed).
    pub fn gates_total(&self) -> u64 {
        self.gates
    }

    /// Joules attributed to one worker.
    pub fn worker_waste_j(&self, worker: usize) -> f64 {
        self.waste.get(worker).map(Kahan::value).unwrap_or(0.0)
    }

    /// Total joules attributed across this replica — conserved against
    /// the replica's accumulator `idle_j + correction_j`.
    pub fn attributed_waste_j(&self) -> f64 {
        self.total.value()
    }

    /// The `n` worst-blamed requests, most wasteful first (cold path:
    /// allocates the return Vec).
    pub fn top_blamed(&self, n: usize) -> Vec<Blame> {
        let mut out = self.blame.clone();
        out.sort_by(|a, b| b.waste_j.total_cmp(&a.waste_j));
        out.truncate(n);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kahan_is_exact_where_naive_summation_drifts() {
        // 1e8-magnitude base + millions of tiny deltas: naive f64
        // summation loses the tail, Neumaier keeps it.
        let mut k = Kahan::default();
        let mut naive = 0.0f64;
        k.add(1e8);
        naive += 1e8;
        for _ in 0..1_000_000 {
            k.add(1e-8);
            naive += 1e-8;
        }
        let want = 1e8 + 1e-2;
        assert!((k.value() - want).abs() <= 1e-9, "kahan {}", k.value());
        // The naive sum demonstrably drifts past the tolerance the
        // conservation identity requires.
        assert!((naive - want).abs() > 1e-9, "naive {naive}");
    }

    #[test]
    fn charges_conserve_and_count() {
        let mut l = GateLedger::new(3, DEFAULT_BLAME_CAP);
        let deltas = [0.5, 0.25, 1.0, 0.125, 2.0];
        let gates = [0usize, 1, 0, 2, 1];
        for (&w, &d) in gates.iter().zip(deltas.iter()) {
            l.charge(w, d);
        }
        assert_eq!(l.gate_counts(), &[2, 2, 1]);
        assert_eq!(l.gates_total(), 5);
        let total: f64 = deltas.iter().sum();
        assert!((l.attributed_waste_j() - total).abs() < 1e-15);
        let per: f64 = (0..3).map(|w| l.worker_waste_j(w)).sum();
        assert!((per - total).abs() < 1e-15);
        // Out-of-range worker ids are ignored, not panics.
        l.charge(99, 1.0);
        assert_eq!(l.gates_total(), 5);
    }

    #[test]
    fn blame_follows_last_admission_and_respects_cap() {
        let mut l = GateLedger::new(1, 2);
        // No admission yet: the charge lands on the worker only.
        l.charge(0, 1.0);
        assert!(l.top_blamed(8).is_empty());
        l.note_admit(0, 7);
        l.charge(0, 2.0);
        l.note_admit(0, 8);
        l.charge(0, 0.5);
        l.charge(0, 0.25);
        let top = l.top_blamed(8);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].request_id, 7);
        assert!((top[0].waste_j - 2.0).abs() < 1e-15);
        assert_eq!(top[1].request_id, 8);
        assert_eq!(top[1].gates, 2);
        // Cap 2 is full: a bigger offender evicts the smaller…
        l.note_admit(0, 9);
        l.charge(0, 5.0);
        let top = l.top_blamed(8);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].request_id, 9);
        assert_eq!(top[1].request_id, 7);
        // …and a tiny one does not displace anything.
        l.note_admit(0, 10);
        l.charge(0, 1e-6);
        assert!(l.top_blamed(8).iter().all(|b| b.request_id != 10));
        // Conservation still holds across evictions (the ledger totals
        // are independent of the blame table).
        let want = 1.0 + 2.0 + 0.5 + 0.25 + 5.0 + 1e-6;
        assert!((l.attributed_waste_j() - want).abs() < 1e-12);
    }
}
