//! Request lifecycle tracing: fixed-shape span events recorded into
//! per-thread flight-recorder ring buffers, merged into a shared
//! [`SpanLog`], and exported as JSONL or Chrome `trace_event` JSON.
//!
//! ## Span schema
//!
//! Every event is a fixed-size [`SpanEvent`] (`Copy`, no heap) with two
//! clocks: `virt_s` — the backend's *virtual* (simulated) clock at the
//! event, on the owning replica's timeline — and `wall_us` — microseconds
//! of real time since the trace epoch (monotonic, process-wide).  Wall
//! time is observability-only: it never feeds back into virtual-time
//! results, so tracing cannot perturb determinism.  The `a`/`b` payload
//! fields are kind-specific:
//!
//! | kind         | `a`                         | `b`                          |
//! |--------------|-----------------------------|------------------------------|
//! | `Arrival`    | prefill tokens              | –                            |
//! | `Route`      | chosen replica's cost       | best rejected candidate cost |
//! | `Admit`      | queue wait (s)              | –                            |
//! | `FirstToken` | exact TTFT (s)              | –                            |
//! | `Finish`     | TPOT (s)                    | output tokens                |
//! | `Shed`       | queue wait so far (s)       | –                            |
//! | `Crash`      | in-flight actives lost      | queued requests stranded     |
//! | `Recover`    | –                           | –                            |
//! | `Retry`      | prefill tokens requeued     | –                            |
//! | `Scale`      | action (0 add, 1 reactivate, 2 drain, 3 remove) | replica speed |
//!
//! ## Flight recorder
//!
//! Each scheduler/pool thread owns a [`Tracer`]: a bounded ring buffer
//! that overwrites its oldest event when full and allocates only at
//! construction — recording is lock-free and allocation-free.  Once per
//! round the owning driver drains every tracer into the shared
//! [`SpanLog`] (one short mutex hold per round, never per request).
//! With tracing disabled, [`Tracer::disabled`] makes every `record` a
//! branch-predicted no-op and holds no buffer at all.

use std::time::Instant;

use crate::util::json::{self, Json};

/// Sentinel for "no replica" / "no worker" on an event.
pub const NO_INDEX: u32 = u32::MAX;

/// Lifecycle stage of a span event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Request entered the backend's wait queue.
    Arrival,
    /// Fleet router chose a replica (`a` = chosen cost, `b` = best
    /// rejected candidate cost; single-group backends skip this stage).
    Route,
    /// Request admitted to a worker's batch (`a` = queue wait, s).
    Admit,
    /// First output token produced (`a` = exact TTFT, s).
    FirstToken,
    /// Request completed (`a` = TPOT s, `b` = output tokens).
    Finish,
    /// Request dropped without completing (`a` = queue wait so far, s).
    Shed,
    /// Replica crashed (`request_id` 0; `a` = in-flight actives lost,
    /// `b` = queued requests stranded on the dead replica).
    Crash,
    /// Replica recovered (`request_id` 0); health goes half-open.
    Recover,
    /// Crash-lost request requeued through the router (`a` = prefill).
    Retry,
    /// Fleet scaling action (`request_id` 0; `a` = action code — 0 cold
    /// add, 1 warm reactivate, 2 drain, 3 drain-for-removal — `b` = the
    /// replica's speed factor), so `/v0/trace` shows autoscale and
    /// admin lifecycle changes interleaved with request lifecycles.
    Scale,
}

impl SpanKind {
    pub fn label(self) -> &'static str {
        match self {
            SpanKind::Arrival => "arrival",
            SpanKind::Route => "route",
            SpanKind::Admit => "admit",
            SpanKind::FirstToken => "first_token",
            SpanKind::Finish => "finish",
            SpanKind::Shed => "shed",
            SpanKind::Crash => "crash",
            SpanKind::Recover => "recover",
            SpanKind::Retry => "retry",
            SpanKind::Scale => "scale",
        }
    }

    /// Causal order within one request's chain — used as a stable sort
    /// tiebreak when wall clocks collide at µs resolution.
    fn rank(self) -> u8 {
        match self {
            SpanKind::Arrival => 0,
            SpanKind::Route => 1,
            SpanKind::Admit => 2,
            SpanKind::FirstToken => 3,
            SpanKind::Finish => 4,
            SpanKind::Shed => 5,
            SpanKind::Crash => 6,
            SpanKind::Recover => 7,
            SpanKind::Retry => 8,
            SpanKind::Scale => 9,
        }
    }
}

/// One fixed-shape lifecycle event.  See the module docs for the
/// per-kind meaning of `a`/`b`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanEvent {
    pub kind: SpanKind,
    pub request_id: u64,
    /// Owning replica, or [`NO_INDEX`] for single-group backends.
    pub replica: u32,
    /// Worker (batch group) within the replica, or [`NO_INDEX`].
    pub worker: u32,
    /// Virtual (simulated) clock at the event, seconds, on the owning
    /// replica's timeline.
    pub virt_s: f64,
    /// Microseconds of wall time since the trace epoch.
    pub wall_us: u64,
    pub a: f64,
    pub b: f64,
}

impl SpanEvent {
    /// JSON object used by both the JSONL export and `/v0/trace`.
    pub fn to_json(&self) -> Json {
        let idx = |v: u32| if v == NO_INDEX { -1.0 } else { v as f64 };
        json::obj(vec![
            ("kind", json::s(self.kind.label())),
            ("request_id", json::num(self.request_id as f64)),
            ("replica", json::num(idx(self.replica))),
            ("worker", json::num(idx(self.worker))),
            ("virt_s", json::num(self.virt_s)),
            ("wall_us", json::num(self.wall_us as f64)),
            ("a", json::num(self.a)),
            ("b", json::num(self.b)),
        ])
    }
}

/// A per-thread flight recorder: bounded ring buffer of [`SpanEvent`]s.
/// All memory is allocated at construction; recording never allocates
/// and never takes a lock.
#[derive(Clone, Debug)]
pub struct Tracer {
    enabled: bool,
    epoch: Instant,
    cap: usize,
    buf: Vec<SpanEvent>,
    /// Index of the oldest event once the ring has wrapped.
    head: usize,
    /// Events overwritten before they could be drained.
    dropped: u64,
}

impl Tracer {
    /// The no-op tracer: `record` does nothing, no buffer is held.
    pub fn disabled() -> Tracer {
        Tracer {
            enabled: false,
            epoch: Instant::now(),
            cap: 0,
            buf: Vec::new(),
            head: 0,
            dropped: 0,
        }
    }

    /// An enabled tracer holding up to `cap` events (≥ 1), stamping
    /// wall clocks relative to `epoch` (share one epoch across all
    /// tracers and the [`SpanLog`] so timestamps are comparable).
    pub fn new(cap: usize, epoch: Instant) -> Tracer {
        let cap = cap.max(1);
        Tracer {
            enabled: true,
            epoch,
            cap,
            buf: Vec::with_capacity(cap),
            head: 0,
            dropped: 0,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record one event (no-op when disabled).
    #[inline]
    pub fn record(
        &mut self,
        kind: SpanKind,
        request_id: u64,
        replica: u32,
        worker: u32,
        virt_s: f64,
        a: f64,
        b: f64,
    ) {
        if !self.enabled {
            return;
        }
        let ev = SpanEvent {
            kind,
            request_id,
            replica,
            worker,
            virt_s,
            wall_us: self.epoch.elapsed().as_micros() as u64,
            a,
            b,
        };
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Move every recorded event into `log` (oldest first) and reset
    /// the ring.  Called once per round by the owning driver.
    pub fn drain_into(&mut self, log: &mut SpanLog) {
        if self.buf.is_empty() {
            log.dropped += std::mem::take(&mut self.dropped);
            return;
        }
        let (newer, older) = self.buf.split_at(self.head);
        // Ring order: [head..] is the older run once wrapped.
        for ev in older.iter().chain(newer.iter()) {
            log.push(*ev);
        }
        log.dropped += std::mem::take(&mut self.dropped);
        self.buf.clear();
        self.head = 0;
    }
}

/// The shared, bounded span store behind `GET /v0/trace`: per-thread
/// tracers drain into it once per round; readers copy slices out under
/// a short lock on the cold path.
#[derive(Debug)]
pub struct SpanLog {
    cap: usize,
    buf: Vec<SpanEvent>,
    head: usize,
    /// Events lost to ring overwrites (here or in any tracer).
    pub dropped: u64,
    /// Wall-clock epoch every tracer should stamp against.
    pub epoch: Instant,
}

impl SpanLog {
    pub fn new(cap: usize) -> SpanLog {
        let cap = cap.max(1);
        SpanLog {
            cap,
            buf: Vec::with_capacity(cap),
            head: 0,
            dropped: 0,
            epoch: Instant::now(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn push(&mut self, ev: SpanEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// The most recent `n` events (optionally only those of request
    /// `id`), returned in causal order (wall clock, then span rank).
    pub fn last(&self, n: usize, id: Option<u64>) -> Vec<SpanEvent> {
        let (newer, older) = self.buf.split_at(self.head);
        let mut out: Vec<SpanEvent> = older
            .iter()
            .chain(newer.iter())
            .filter(|ev| id.map(|want| ev.request_id == want).unwrap_or(true))
            .copied()
            .collect();
        out.sort_by_key(|ev| (ev.wall_us, ev.kind.rank()));
        if out.len() > n {
            out.drain(..out.len() - n);
        }
        out
    }
}

/// Render events as JSONL: one JSON object per line (the `/v0/trace`
/// default and the CI artifact format).
pub fn to_jsonl(events: &[SpanEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&ev.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Render events as a Chrome `trace_event` document (load in
/// `chrome://tracing` or Perfetto): instant events keyed by
/// replica (pid) / worker (tid).  `dropped` is the flight-recorder
/// drop counter, carried in the document's `metadata` so the Chrome
/// export states its own completeness like the JSONL header line does.
pub fn to_chrome(events: &[SpanEvent], dropped: u64) -> String {
    let idx = |v: u32| if v == NO_INDEX { -1.0 } else { v as f64 };
    let evs: Vec<Json> = events
        .iter()
        .map(|ev| {
            json::obj(vec![
                ("name", json::s(ev.kind.label())),
                ("cat", json::s("bfio")),
                ("ph", json::s("i")),
                ("s", json::s("g")),
                ("ts", json::num(ev.wall_us as f64)),
                ("pid", json::num(idx(ev.replica))),
                ("tid", json::num(idx(ev.worker))),
                (
                    "args",
                    json::obj(vec![
                        ("request_id", json::num(ev.request_id as f64)),
                        ("virt_s", json::num(ev.virt_s)),
                        ("a", json::num(ev.a)),
                        ("b", json::num(ev.b)),
                    ]),
                ),
            ])
        })
        .collect();
    json::obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", json::s("ms")),
        (
            "metadata",
            json::obj(vec![("dropped", json::num(dropped as f64))]),
        ),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: &mut Tracer, kind: SpanKind, id: u64) {
        t.record(kind, id, 0, 0, 0.0, 0.0, 0.0);
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut t = Tracer::disabled();
        ev(&mut t, SpanKind::Arrival, 1);
        assert!(!t.is_enabled());
        let mut log = SpanLog::new(8);
        t.drain_into(&mut log);
        assert!(log.is_empty());
    }

    #[test]
    fn ring_overwrites_oldest_and_keeps_order() {
        let epoch = Instant::now();
        let mut t = Tracer::new(3, epoch);
        for id in 1..=5 {
            ev(&mut t, SpanKind::Arrival, id);
        }
        let mut log = SpanLog::new(8);
        t.drain_into(&mut log);
        assert_eq!(log.len(), 3);
        assert_eq!(log.dropped, 2);
        let ids: Vec<u64> = log.last(10, None).iter().map(|e| e.request_id).collect();
        assert_eq!(ids, vec![3, 4, 5], "oldest events overwritten, order kept");
    }

    #[test]
    fn span_log_filters_by_request_and_caps_last_n() {
        let epoch = Instant::now();
        let mut t = Tracer::new(64, epoch);
        for id in [7u64, 8, 7, 9, 7] {
            ev(&mut t, SpanKind::Arrival, id);
        }
        let mut log = SpanLog::new(64);
        t.drain_into(&mut log);
        assert_eq!(log.last(10, Some(7)).len(), 3);
        assert_eq!(log.last(2, None).len(), 2);
        assert_eq!(log.last(10, Some(404)).len(), 0);
    }

    #[test]
    fn causal_chain_sorts_by_wall_then_rank() {
        let epoch = Instant::now();
        let mut t = Tracer::new(16, epoch);
        // Record out of causal order with identical wall stamps is hard
        // to force; instead check the rank tiebreak via direct pushes.
        let mut log = SpanLog::new(16);
        for kind in [SpanKind::Finish, SpanKind::Arrival, SpanKind::Admit] {
            log.push(SpanEvent {
                kind,
                request_id: 1,
                replica: 0,
                worker: 0,
                virt_s: 0.0,
                wall_us: 100,
                a: 0.0,
                b: 0.0,
            });
        }
        let kinds: Vec<SpanKind> = log.last(10, Some(1)).iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec![SpanKind::Arrival, SpanKind::Admit, SpanKind::Finish]);
        ev(&mut t, SpanKind::Arrival, 2);
        t.drain_into(&mut log);
        assert_eq!(log.len(), 4);
    }

    #[test]
    fn jsonl_and_chrome_exports_parse() {
        let events = vec![
            SpanEvent {
                kind: SpanKind::Arrival,
                request_id: 42,
                replica: 1,
                worker: NO_INDEX,
                virt_s: 0.5,
                wall_us: 10,
                a: 16.0,
                b: 0.0,
            },
            SpanEvent {
                kind: SpanKind::Finish,
                request_id: 42,
                replica: 1,
                worker: 3,
                virt_s: 1.5,
                wall_us: 90,
                a: 0.01,
                b: 8.0,
            },
        ];
        let jsonl = to_jsonl(&events);
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("kind").unwrap().as_str().unwrap(), "arrival");
        assert_eq!(first.get("request_id").unwrap().as_u64().unwrap(), 42);
        assert_eq!(first.get("worker").unwrap().as_f64().unwrap(), -1.0);
        let chrome = Json::parse(&to_chrome(&events, 7)).unwrap();
        let evs = chrome.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[1].get("name").unwrap().as_str().unwrap(), "finish");
        assert_eq!(
            evs[1].get("args").unwrap().get("request_id").unwrap().as_u64().unwrap(),
            42
        );
        // The drop counter rides in metadata, mirroring the JSONL header.
        assert_eq!(
            chrome.get("metadata").unwrap().get("dropped").unwrap().as_u64().unwrap(),
            7
        );
    }
}
