//! Bounded windowed time-series ring for the live dashboard.
//!
//! Every `window` rounds the fleet core folds one [`SeriesPoint`] into
//! the ring: per-window arrival/completion counts, the fleet Eq. 2
//! imbalance, the straggler gap, the Theorem-4 energy decomposition
//! (as window deltas of the cumulative accumulators), SLO-goodput, and
//! a compact per-replica row (health / penalty / gate-share / load).
//!
//! The ring is bounded by `cap` points with oldest-first eviction and
//! is **zero-alloc in steady state**: points are laid down once, then
//! reused in place (the per-replica `Vec` is cleared, not rebuilt), so
//! recording costs O(R) stores and no heap traffic once the ring has
//! filled and the fleet size is stable.  The gateway publishes a
//! mirror via [`SeriesRing::copy_from`] (same in-place discipline,
//! skipped entirely when the version counter is unchanged) and renders
//! it as JSON on `GET /v0/series?last=N`; `GET /v0/dash` serves
//! [`DASH_HTML`], a dependency-free single-file dashboard polling that
//! endpoint.

use crate::util::json::{arr, num, obj, s, Json};

/// Health codes carried per replica point (compact alternative to the
/// label strings; see [`health_label`]).
pub const HEALTH_HEALTHY: u8 = 0;
pub const HEALTH_SUSPECT: u8 = 1;
pub const HEALTH_DOWN: u8 = 2;
pub const HEALTH_RECOVERING: u8 = 3;

/// Label for a health code (mirrors `fault::HealthState::label`).
pub fn health_label(code: u8) -> &'static str {
    match code {
        HEALTH_HEALTHY => "healthy",
        HEALTH_SUSPECT => "suspect",
        HEALTH_DOWN => "down",
        HEALTH_RECOVERING => "recovering",
        _ => "unknown",
    }
}

/// Cumulative counters sampled at a window boundary; the ring turns
/// consecutive samples into per-window deltas.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SeriesTotals {
    pub arrivals: u64,
    pub completions: u64,
    pub energy_j: f64,
    pub useful_j: f64,
    pub idle_j: f64,
    pub correction_j: f64,
}

/// One replica's row within a point.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReplicaPoint {
    pub id: usize,
    pub health: u8,
    pub penalty: f64,
    /// This replica's share of all barrier-step gates so far (straggler
    /// attribution; sums to ~1 across live replicas once steps exist).
    pub gate_share: f64,
    pub load: f64,
}

/// One window's sample.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SeriesPoint {
    pub round: u64,
    pub clock_s: f64,
    /// Per-window deltas of the cumulative counters.
    pub arrivals: u64,
    pub completions: u64,
    pub energy_j: f64,
    pub useful_j: f64,
    pub idle_j: f64,
    pub correction_j: f64,
    /// Instantaneous fleet Eq. 2 imbalance at the boundary.
    pub imbalance: f64,
    /// Max-minus-min live replica clock at the boundary.
    pub straggler_gap_s: f64,
    /// Cumulative SLO-goodput at the boundary.
    pub goodput: f64,
    pub replicas: Vec<ReplicaPoint>,
}

/// The bounded ring itself.
#[derive(Clone, Debug)]
pub struct SeriesRing {
    window: u64,
    cap: usize,
    buf: Vec<SeriesPoint>,
    /// Index of the oldest point.
    head: usize,
    len: usize,
    last: SeriesTotals,
    version: u64,
}

impl SeriesRing {
    pub fn new(window: u64, cap: usize) -> SeriesRing {
        SeriesRing {
            window: window.max(1),
            cap: cap.max(1),
            buf: Vec::new(),
            head: 0,
            len: 0,
            last: SeriesTotals::default(),
            version: 0,
        }
    }

    pub fn window(&self) -> u64 {
        self.window
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bumped on every record; lets mirrors skip no-op copies.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Should round `round` close a window?  (`round` is 1-based by
    /// the time the core's epilogue runs.)
    pub fn due(&self, round: u64) -> bool {
        round % self.window == 0
    }

    /// Record one window boundary.  `totals` are the *cumulative*
    /// counters; the ring stores their delta against the previous
    /// boundary.  Returns the point's replica Vec, cleared, for the
    /// caller to fill — in place, no allocation once warm.
    pub fn record(
        &mut self,
        round: u64,
        clock_s: f64,
        totals: SeriesTotals,
        imbalance: f64,
        straggler_gap_s: f64,
        goodput: f64,
    ) -> &mut Vec<ReplicaPoint> {
        self.version += 1;
        let idx = if self.len < self.cap {
            let idx = (self.head + self.len) % self.cap;
            if idx == self.buf.len() {
                self.buf.push(SeriesPoint::default());
            }
            self.len += 1;
            idx
        } else {
            let idx = self.head;
            self.head = (self.head + 1) % self.cap;
            idx
        };
        let p = &mut self.buf[idx];
        p.round = round;
        p.clock_s = clock_s;
        p.arrivals = totals.arrivals.saturating_sub(self.last.arrivals);
        p.completions = totals.completions.saturating_sub(self.last.completions);
        p.energy_j = (totals.energy_j - self.last.energy_j).max(0.0);
        p.useful_j = (totals.useful_j - self.last.useful_j).max(0.0);
        p.idle_j = (totals.idle_j - self.last.idle_j).max(0.0);
        p.correction_j =
            (totals.correction_j - self.last.correction_j).max(0.0);
        p.imbalance = imbalance;
        p.straggler_gap_s = straggler_gap_s;
        p.goodput = goodput;
        p.replicas.clear();
        self.last = totals;
        &mut self.buf[idx].replicas
    }

    /// Point `i` in oldest-first order (`i < len`).
    pub fn get(&self, i: usize) -> Option<&SeriesPoint> {
        (i < self.len).then(|| &self.buf[(self.head + i) % self.cap])
    }

    /// Oldest-first iteration.
    pub fn points(&self) -> impl Iterator<Item = &SeriesPoint> {
        (0..self.len).map(move |i| &self.buf[(self.head + i) % self.cap])
    }

    /// Mirror `src` into `self` in place: per-point field copies with
    /// the replica Vecs reused, and a version check that makes the
    /// steady-state no-change publish free.
    pub fn copy_from(&mut self, src: &SeriesRing) {
        if self.version == src.version
            && self.window == src.window
            && self.cap == src.cap
        {
            return;
        }
        self.window = src.window;
        self.cap = src.cap;
        self.head = 0;
        self.len = src.len;
        self.last = src.last;
        self.version = src.version;
        if self.buf.len() > src.len {
            self.buf.truncate(src.len);
        }
        for (i, sp) in src.points().enumerate() {
            if i == self.buf.len() {
                self.buf.push(SeriesPoint::default());
            }
            let dst = &mut self.buf[i];
            let keep = std::mem::take(&mut dst.replicas);
            *dst = SeriesPoint { replicas: keep, ..SeriesPoint::default() };
            dst.round = sp.round;
            dst.clock_s = sp.clock_s;
            dst.arrivals = sp.arrivals;
            dst.completions = sp.completions;
            dst.energy_j = sp.energy_j;
            dst.useful_j = sp.useful_j;
            dst.idle_j = sp.idle_j;
            dst.correction_j = sp.correction_j;
            dst.imbalance = sp.imbalance;
            dst.straggler_gap_s = sp.straggler_gap_s;
            dst.goodput = sp.goodput;
            dst.replicas.clear();
            dst.replicas.extend_from_slice(&sp.replicas);
        }
    }

    /// Fold another ring's points into this one by matching round —
    /// the per-replica-shard merge used in tests and offline analysis.
    /// Additive fields (arrivals, completions, energy terms, Eq. 2
    /// imbalance, which is a sum of per-group terms) add exactly;
    /// the straggler gap takes the max; goodput is
    /// completion-weighted; replica rows concatenate.  Points whose
    /// rounds exist only in `other` are appended in order.
    pub fn merge_aligned(&mut self, other: &SeriesRing) {
        self.version += 1;
        for op in other.points() {
            let mut found = false;
            for i in 0..self.len {
                let idx = (self.head + i) % self.cap;
                if self.buf[idx].round == op.round {
                    let p = &mut self.buf[idx];
                    let done = p.completions + op.completions;
                    if done > 0 {
                        p.goodput = (p.goodput * p.completions as f64
                            + op.goodput * op.completions as f64)
                            / done as f64;
                    }
                    p.arrivals += op.arrivals;
                    p.completions += op.completions;
                    p.energy_j += op.energy_j;
                    p.useful_j += op.useful_j;
                    p.idle_j += op.idle_j;
                    p.correction_j += op.correction_j;
                    p.imbalance += op.imbalance;
                    p.straggler_gap_s =
                        p.straggler_gap_s.max(op.straggler_gap_s);
                    p.clock_s = p.clock_s.max(op.clock_s);
                    p.replicas.extend_from_slice(&op.replicas);
                    found = true;
                    break;
                }
            }
            if !found {
                let slot = self.record(
                    op.round,
                    op.clock_s,
                    self.last, // zero delta; fields overwritten below
                    op.imbalance,
                    op.straggler_gap_s,
                    op.goodput,
                );
                slot.extend_from_slice(&op.replicas);
                let idx = (self.head + self.len - 1) % self.cap;
                self.buf[idx].arrivals = op.arrivals;
                self.buf[idx].completions = op.completions;
                self.buf[idx].energy_j = op.energy_j;
                self.buf[idx].useful_j = op.useful_j;
                self.buf[idx].idle_j = op.idle_j;
                self.buf[idx].correction_j = op.correction_j;
            }
        }
    }

    /// Render the newest `last` points as the `/v0/series` JSON
    /// document (cold path; allocates freely).
    pub fn to_json(&self, last: usize) -> String {
        let n = last.min(self.len);
        let skip = self.len - n;
        let pts = self.points().skip(skip).map(|p| {
            obj(vec![
                ("round", num(p.round as f64)),
                ("clock_s", num(p.clock_s)),
                ("arrivals", num(p.arrivals as f64)),
                ("completions", num(p.completions as f64)),
                ("imbalance", num(p.imbalance)),
                ("straggler_gap_s", num(p.straggler_gap_s)),
                ("energy_j", num(p.energy_j)),
                ("useful_j", num(p.useful_j)),
                ("idle_j", num(p.idle_j)),
                ("correction_j", num(p.correction_j)),
                ("goodput", num(p.goodput)),
                (
                    "replicas",
                    arr(p.replicas.iter().map(|r| {
                        obj(vec![
                            ("id", num(r.id as f64)),
                            ("health", s(health_label(r.health))),
                            ("penalty", num(r.penalty)),
                            ("gate_share", num(r.gate_share)),
                            ("load", num(r.load)),
                        ])
                    })),
                ),
            ])
        });
        obj(vec![
            ("window", num(self.window as f64)),
            ("cap", num(self.cap as f64)),
            ("len", num(self.len as f64)),
            ("points", arr(pts)),
        ])
        .to_string()
    }
}

/// The `/v0/dash` page: a self-contained, dependency-free HTML file
/// whose inline script polls `/v0/series` and redraws three canvas
/// strips (imbalance + straggler gap, Theorem-4 energy split, traffic
/// + goodput) plus a live replica table.  No external assets, no
/// frameworks — it works from `curl | browser` on an air-gapped box.
pub const DASH_HTML: &str = r#"<!doctype html>
<html lang="en">
<head>
<meta charset="utf-8">
<title>bfio imbalance observatory</title>
<style>
 body{background:#10141a;color:#cdd6e0;font:13px/1.5 monospace;margin:18px}
 h1{font-size:16px;color:#e6edf3} h2{font-size:13px;color:#8ab4f8;margin:14px 0 4px}
 canvas{background:#161b24;border:1px solid #2a3240;display:block;width:100%;height:120px}
 table{border-collapse:collapse;margin-top:6px}
 td,th{border:1px solid #2a3240;padding:2px 8px;text-align:right}
 th{color:#8ab4f8} .h0{color:#7ce38b}.h1{color:#e3b341}.h2{color:#f85149}.h3{color:#79c0ff}
 #meta{color:#768390}
 .leg{font-size:11px;color:#768390}
</style>
</head>
<body>
<h1>bfio imbalance observatory</h1>
<div id="meta">connecting…</div>
<h2>Eq. 2 imbalance (tokens) / straggler gap (s)</h2>
<div class="leg">imbalance <span style="color:#e3b341">&#9632;</span> · gap <span style="color:#f85149">&#9632;</span></div>
<canvas id="imb"></canvas>
<h2>Theorem-4 energy per window (J)</h2>
<div class="leg">useful <span style="color:#7ce38b">&#9632;</span> · idle <span style="color:#e3b341">&#9632;</span> · correction <span style="color:#f85149">&#9632;</span></div>
<canvas id="energy"></canvas>
<h2>traffic per window / SLO-goodput</h2>
<div class="leg">arrivals <span style="color:#79c0ff">&#9632;</span> · completions <span style="color:#7ce38b">&#9632;</span> · goodput <span style="color:#cdd6e0">&#9632;</span></div>
<canvas id="traffic"></canvas>
<h2>replicas</h2>
<table id="reps"><tr><th>id</th><th>health</th><th>penalty</th><th>gate share</th><th>load</th></tr></table>
<script>
function draw(id, series, colors, norm) {
  var cv = document.getElementById(id);
  cv.width = cv.clientWidth; cv.height = cv.clientHeight;
  var g = cv.getContext('2d'), W = cv.width, H = cv.height;
  g.clearRect(0, 0, W, H);
  var max = 1e-12;
  series.forEach(function (ys) {
    ys.forEach(function (y) { if (y > max) max = y; });
  });
  if (norm) max = norm;
  series.forEach(function (ys, si) {
    g.strokeStyle = colors[si]; g.beginPath();
    ys.forEach(function (y, i) {
      var x = ys.length > 1 ? i * (W - 8) / (ys.length - 1) + 4 : W / 2;
      var yy = H - 6 - (y / max) * (H - 12);
      if (i === 0) g.moveTo(x, yy); else g.lineTo(x, yy);
    });
    g.stroke();
  });
  g.fillStyle = '#768390'; g.fillText(max.toPrecision(3), 4, 12);
}
function tick() {
  fetch('/v0/series?last=128').then(function (r) {
    if (!r.ok) throw new Error('HTTP ' + r.status);
    return r.json();
  }).then(function (d) {
    var p = d.points || [];
    document.getElementById('meta').textContent =
      p.length + ' points · window ' + d.window + ' rounds · cap ' + d.cap +
      (p.length ? ' · round ' + p[p.length - 1].round : '');
    var col = function (k) { return p.map(function (q) { return q[k]; }); };
    draw('imb', [col('imbalance'), col('straggler_gap_s')], ['#e3b341', '#f85149']);
    draw('energy', [col('useful_j'), col('idle_j'), col('correction_j')],
         ['#7ce38b', '#e3b341', '#f85149']);
    draw('traffic', [col('arrivals'), col('completions'),
                     col('goodput').map(function (g0) {
                       var m = Math.max.apply(null, col('arrivals').concat([1]));
                       return g0 * m;
                     })],
         ['#79c0ff', '#7ce38b', '#cdd6e0']);
    var t = document.getElementById('reps');
    while (t.rows.length > 1) t.deleteRow(1);
    var reps = p.length ? p[p.length - 1].replicas : [];
    reps.forEach(function (r0) {
      var row = t.insertRow(-1);
      row.insertCell(-1).textContent = r0.id;
      var hc = row.insertCell(-1);
      hc.textContent = r0.health;
      hc.className = { healthy: 'h0', suspect: 'h1', down: 'h2', recovering: 'h3' }[r0.health] || '';
      row.insertCell(-1).textContent = r0.penalty.toFixed(3);
      row.insertCell(-1).textContent = (100 * r0.gate_share).toFixed(1) + '%';
      row.insertCell(-1).textContent = r0.load.toFixed(1);
    });
  }).catch(function (e) {
    document.getElementById('meta').textContent = 'series unavailable: ' + e;
  });
}
tick(); setInterval(tick, 2000);
</script>
</body>
</html>
"#;

#[cfg(test)]
mod tests {
    use super::*;

    fn totals(a: u64, c: u64, e: f64) -> SeriesTotals {
        SeriesTotals {
            arrivals: a,
            completions: c,
            energy_j: e,
            useful_j: e * 0.5,
            idle_j: e * 0.3,
            correction_j: e * 0.2,
        }
    }

    #[test]
    fn ring_bounds_and_oldest_first_eviction() {
        let mut r = SeriesRing::new(4, 3);
        assert!(r.due(4) && r.due(8) && !r.due(5));
        for i in 1..=5u64 {
            let reps =
                r.record(i * 4, i as f64, totals(i * 10, i * 2, i as f64), 0.0, 0.0, 1.0);
            reps.push(ReplicaPoint { id: 0, ..ReplicaPoint::default() });
            assert!(r.len() <= r.capacity(), "ring must never exceed cap");
        }
        assert_eq!(r.len(), 3);
        let rounds: Vec<u64> = r.points().map(|p| p.round).collect();
        assert_eq!(rounds, vec![12, 16, 20], "oldest evicted first");
        // Deltas, not cumulative values, are stored.
        assert_eq!(r.get(0).unwrap().arrivals, 10);
        assert_eq!(r.get(2).unwrap().completions, 2);
        assert!((r.get(1).unwrap().energy_j - 1.0).abs() < 1e-12);
        assert_eq!(r.get(2).unwrap().replicas.len(), 1);
        assert!(r.get(3).is_none());
    }

    #[test]
    fn merge_across_replica_shards_is_exact() {
        // Two shards sampling the same window boundaries merge to the
        // exact union on every additive field.
        let mut a = SeriesRing::new(2, 8);
        let mut b = SeriesRing::new(2, 8);
        for i in 1..=4u64 {
            a.record(i * 2, i as f64, totals(i * 3, i, i as f64 * 2.0), 1.5, 0.25, 1.0)
                .push(ReplicaPoint { id: 0, ..ReplicaPoint::default() });
            b.record(i * 2, i as f64, totals(i * 5, i * 2, i as f64 * 4.0), 2.5, 0.5, 0.5)
                .push(ReplicaPoint { id: 1, ..ReplicaPoint::default() });
        }
        let mut merged = SeriesRing::new(2, 8);
        merged.copy_from(&a);
        merged.merge_aligned(&b);
        assert_eq!(merged.len(), 4);
        for (i, p) in merged.points().enumerate() {
            let (pa, pb) = (a.get(i).unwrap(), b.get(i).unwrap());
            assert_eq!(p.arrivals, pa.arrivals + pb.arrivals);
            assert_eq!(p.completions, pa.completions + pb.completions);
            assert_eq!(p.energy_j, pa.energy_j + pb.energy_j, "exact add");
            assert_eq!(p.imbalance, pa.imbalance + pb.imbalance);
            assert_eq!(p.straggler_gap_s, 0.5);
            assert_eq!(p.replicas.len(), 2);
        }
        // Disjoint rounds append instead of merging.
        let mut c = SeriesRing::new(2, 8);
        c.record(100, 9.0, totals(1, 1, 1.0), 0.0, 0.0, 1.0);
        merged.merge_aligned(&c);
        assert_eq!(merged.len(), 5);
        assert_eq!(merged.get(4).unwrap().round, 100);
    }

    #[test]
    fn copy_from_mirrors_and_skips_unchanged_versions() {
        let mut src = SeriesRing::new(8, 4);
        src.record(8, 1.0, totals(4, 2, 8.0), 3.0, 0.1, 0.9)
            .push(ReplicaPoint { id: 1, health: HEALTH_SUSPECT, ..ReplicaPoint::default() });
        let mut dst = SeriesRing::new(1, 1);
        dst.copy_from(&src);
        assert_eq!(dst.len(), 1);
        assert_eq!(dst.capacity(), 4);
        assert_eq!(dst.get(0).unwrap(), src.get(0).unwrap());
        let v = dst.version();
        dst.copy_from(&src); // no change → no work, same version
        assert_eq!(dst.version(), v);
    }

    #[test]
    fn json_shape_parses_and_respects_last() {
        let mut r = SeriesRing::new(1, 8);
        for i in 1..=6u64 {
            r.record(i, i as f64, totals(i, i, i as f64), 0.5, 0.0, 1.0)
                .push(ReplicaPoint {
                    id: 3,
                    health: HEALTH_HEALTHY,
                    penalty: 1.0,
                    gate_share: 0.25,
                    load: 7.0,
                });
        }
        let doc = Json::parse(&r.to_json(2)).unwrap();
        assert_eq!(doc.get("len").unwrap().as_f64().unwrap(), 6.0);
        let pts = doc.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 2, "last=2 returns the newest two");
        assert_eq!(pts[1].get("round").unwrap().as_f64().unwrap(), 6.0);
        let reps = pts[1].get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(reps[0].get("health").unwrap().as_str().unwrap(), "healthy");
        assert_eq!(reps[0].get("gate_share").unwrap().as_f64().unwrap(), 0.25);
        // The dashboard is self-contained: no external fetches beyond
        // the series endpoint, and it names the endpoint it polls.
        assert!(DASH_HTML.contains("/v0/series"));
        assert!(!DASH_HTML.contains("http://"));
        assert!(!DASH_HTML.contains("https://"));
    }
}
