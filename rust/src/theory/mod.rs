//! Theory layer: closed-form theorem bounds and empirical validation
//! drivers for the paper's guarantees.
//!
//! * Theorem 1 (homogeneous decode): `IIR >= c·κ0·√(B log G)·G/(G−1)`.
//! * Theorem 2 (geometric decode):   `IIR >= c·(p/s_max)·σ_snap·√(B log G)·G/(G−1)`
//!   with `σ_snap² = σ_s² + (1−p)/p²`.
//! * Theorem 3 (general drift): same scaling with `σ_s` in place of
//!   `σ_snap`.
//! * Theorem 4 / Corollary 1: energy-saving bounds (see [`crate::energy`]).
//!
//! [`measure_iir`] estimates the ratio empirically by running FCFS and
//! BF-IO(H=0) on a common overloaded trace; the `bfio theory` CLI sweeps
//! (B, G) and reports the fit of measured IIR against `√(B log G)`.

use crate::config::SimConfig;
use crate::policies::bfio::BfIo;
use crate::policies::fcfs::Fcfs;
use crate::sim::Simulator;
use crate::util::rng::Rng;
use crate::util::stats::linear_fit;
use crate::workload::adversarial::overloaded_trace;
use crate::workload::{Drift, LengthSampler};

/// Snapshot variance σ_snap² = σ_s² + (1−p)/p² (Theorem 2).
pub fn sigma_snap_sq(sigma_s_sq: f64, p: f64) -> f64 {
    sigma_s_sq + (1.0 - p) / (p * p)
}

/// Theorem 1's lower-bound *shape* (up to the universal constant c):
/// `κ0·√(B log G)·G/(G−1)`.
pub fn thm1_shape(kappa0: f64, b: usize, g: usize) -> f64 {
    assert!(g >= 2);
    kappa0 * ((b as f64) * (g as f64).ln()).sqrt() * g as f64 / (g as f64 - 1.0)
}

/// Theorem 2's lower-bound shape:
/// `(p/s_max)·σ_snap·√(B log G)·G/(G−1)`.
pub fn thm2_shape(p: f64, s_max: f64, sigma_s_sq: f64, b: usize, g: usize) -> f64 {
    assert!(g >= 2);
    (p / s_max)
        * sigma_snap_sq(sigma_s_sq, p).sqrt()
        * ((b as f64) * (g as f64).ln()).sqrt()
        * g as f64
        / (g as f64 - 1.0)
}

/// Theorem 3's lower-bound shape (general non-decreasing drift):
/// `(p·σ_s/s_max)·√(B log G)·G/(G−1)`.
pub fn thm3_shape(p: f64, s_max: f64, sigma_s: f64, b: usize, g: usize) -> f64 {
    assert!(g >= 2);
    (p * sigma_s / s_max) * ((b as f64) * (g as f64).ln()).sqrt() * g as f64
        / (g as f64 - 1.0)
}

/// One empirical IIR measurement.
#[derive(Clone, Debug)]
pub struct IirPoint {
    pub b: usize,
    pub g: usize,
    pub fcfs_imbalance: f64,
    pub bfio_imbalance: f64,
    pub iir: f64,
    /// √(B log G) — the theory's predictor variable.
    pub shape: f64,
}

/// Measure IIR = AvgImbalance(FCFS)/AvgImbalance(BF-IO(H=0)) on a common
/// overloaded trace with the given sampler and drift.
pub fn measure_iir(
    sampler: &dyn LengthSampler,
    drift: Drift,
    b: usize,
    g: usize,
    steps: u64,
    seed: u64,
) -> IirPoint {
    let cfg = SimConfig {
        g,
        b,
        drift,
        max_steps: steps,
        warmup_steps: steps / 5,
        seed,
        ..SimConfig::default()
    };
    let mut rng = Rng::new(seed);
    let trace = overloaded_trace(sampler, g, b, steps, 3.0, &mut rng);
    let sim = Simulator::new(cfg);
    let f = sim.run(&trace, &mut Fcfs::new());
    let bf = sim.run(&trace, &mut BfIo::with_horizon(0));
    let iir = f.report.avg_imbalance / bf.report.avg_imbalance.max(1e-12);
    IirPoint {
        b,
        g,
        fcfs_imbalance: f.report.avg_imbalance,
        bfio_imbalance: bf.report.avg_imbalance,
        iir,
        shape: ((b as f64) * (g as f64).ln()).sqrt(),
    }
}

/// Fit measured IIR against the √(B log G) shape; returns (slope,
/// intercept, r²) of `iir ~ a + c·shape`.  Theorems 1–3 predict a
/// positive slope with good linearity across the sweep.
pub fn fit_iir_scaling(points: &[IirPoint]) -> (f64, f64, f64) {
    let xs: Vec<f64> = points.iter().map(|p| p.shape).collect();
    let ys: Vec<f64> = points.iter().map(|p| p.iir).collect();
    let (a, c, r2) = linear_fit(&xs, &ys);
    (c, a, r2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::GeometricSampler;

    #[test]
    fn shapes_grow_with_scale() {
        assert!(thm1_shape(0.2, 128, 64) > thm1_shape(0.2, 64, 64));
        assert!(thm1_shape(0.2, 64, 128) > thm1_shape(0.2, 64, 64));
        assert!(thm2_shape(0.1, 100.0, 25.0, 128, 64)
            > thm2_shape(0.1, 100.0, 25.0, 64, 64));
        assert!(thm3_shape(0.1, 100.0, 5.0, 128, 64) > 0.0);
    }

    #[test]
    fn sigma_snap_dominated_by_geometric_tail_for_small_p() {
        // (1-p)/p² >> σ_s² when p is small.
        let s = sigma_snap_sq(25.0, 0.01);
        assert!(s > 9_000.0);
        // p = 1 -> no age variance.
        assert_eq!(sigma_snap_sq(25.0, 1.0), 25.0);
    }

    #[test]
    fn g_over_g_minus_1_factor() {
        // factor decreases toward 1 as G grows
        let f2 = thm1_shape(1.0, 1, 2) / (2.0f64.ln()).sqrt();
        let f100 = thm1_shape(1.0, 1, 100) / (100.0f64.ln()).sqrt();
        assert!(f2 > f100);
        assert!((f100 - 100.0 / 99.0).abs() < 1e-9);
    }

    #[test]
    fn empirical_iir_exceeds_one_and_grows() {
        // Small but real: BF-IO beats FCFS, and IIR grows with B.
        let sampler = GeometricSampler::new(1, 200, 0.2);
        let small = measure_iir(&sampler, Drift::Unit, 4, 4, 150, 42);
        let big = measure_iir(&sampler, Drift::Unit, 16, 4, 150, 42);
        assert!(small.iir > 1.0, "IIR {}", small.iir);
        assert!(big.iir > small.iir, "big {} small {}", big.iir, small.iir);
    }

    #[test]
    fn fit_recovers_positive_slope() {
        let pts = vec![
            IirPoint { b: 4, g: 4, fcfs_imbalance: 0.0, bfio_imbalance: 0.0, iir: 2.0, shape: 2.0 },
            IirPoint { b: 16, g: 4, fcfs_imbalance: 0.0, bfio_imbalance: 0.0, iir: 4.0, shape: 4.0 },
            IirPoint { b: 64, g: 4, fcfs_imbalance: 0.0, bfio_imbalance: 0.0, iir: 8.0, shape: 8.0 },
        ];
        let (slope, _, r2) = fit_iir_scaling(&pts);
        assert!(slope > 0.9);
        assert!(r2 > 0.99);
    }
}
