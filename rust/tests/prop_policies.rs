//! Property suites over random routing instances: feasibility, work
//! conservation, the BF-IO balance property, and solver optimality —
//! the (IO) invariants of Section 4.

use bfio_serve::config::BfIoConfig;
use bfio_serve::policies::bfio::objective::WindowedLoads;
use bfio_serve::policies::bfio::{exact::solve_exact, BfIo};
use bfio_serve::policies::{
    by_name, validate_assignments, ActiveView, AssignCtx, Policy, WaitingView,
    WorkerView,
};
use bfio_serve::util::prop::Prop;
use bfio_serve::util::rng::Rng;
use bfio_serve::workload::Drift;

/// Random decision instance generator shared by the suites.
#[derive(Debug)]
struct Instance {
    b: usize,
    workers: Vec<WorkerView>,
    waiting: Vec<WaitingView>,
    drift: Vec<f64>,
}

fn gen_instance(r: &mut Rng) -> Instance {
    let g = 2 + r.below_usize(12);
    let b = 1 + r.below_usize(12);
    let workers: Vec<WorkerView> = (0..g)
        .map(|_| {
            let occupied = r.below_usize(b + 1);
            let active: Vec<ActiveView> = (0..occupied)
                .map(|_| ActiveView::fresh(1.0 + r.f64() * 1000.0, 1 + r.below(50)))
                .collect();
            WorkerView {
                load: active.iter().map(|a| a.load).sum(),
                free_slots: b - occupied,
                active,
            }
        })
        .collect();
    let w = r.below_usize(40);
    let waiting: Vec<WaitingView> = (0..w)
        .map(|i| WaitingView {
            idx: i,
            prefill: 1.0 + r.f64() * 500.0,
            arrival_step: 0,
        })
        .collect();
    let h = r.below_usize(20);
    let drift = Drift::Unit.cumulative(0, h.max(1));
    Instance { b, workers, waiting, drift }
}

#[test]
fn prop_all_policies_feasible_and_work_conserving() {
    let names = [
        "fcfs", "jsq", "rr", "pow2", "least", "minmin", "maxmin", "bfio:0",
        "bfio:10",
    ];
    Prop::new(200).check("feasible+conserving", gen_instance, |inst| {
        let ctx = AssignCtx {
            step: 0,
            batch_cap: inst.b,
            workers: &inst.workers,
            waiting: &inst.waiting,
            cum_drift: &inst.drift,
        };
        let u = ctx.u_k();
        for name in names {
            let mut p = by_name(name).unwrap();
            let a = p.assign(&ctx, &mut Rng::new(5));
            validate_assignments(&ctx, &a)
                .map_err(|e| format!("{name}: {e}"))?;
            // all of these are work-conserving: exactly U(k) admitted
            if a.len() != u {
                return Err(format!("{name}: admitted {} != U(k) {}", a.len(), u));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_throttled_feasible_but_bounded() {
    Prop::new(100).check("throttled", gen_instance, |inst| {
        let ctx = AssignCtx {
            step: 0,
            batch_cap: inst.b,
            workers: &inst.workers,
            waiting: &inst.waiting,
            cum_drift: &inst.drift,
        };
        let mut p = by_name("throttled:0.5").unwrap();
        let a = p.assign(&ctx, &mut Rng::new(5));
        validate_assignments(&ctx, &a).map_err(|e| e.to_string())?;
        if a.len() > ctx.u_k() {
            return Err("throttled admitted more than U(k)".into());
        }
        Ok(())
    });
}

#[test]
fn prop_bfio_h0_empty_cluster_smax_balanced() {
    // Lemma 1: on an empty cluster with equal capacities, the fresh
    // assignment's max-min gap is at most s_max (for the optimum; the
    // heuristic is allowed one extra s_max of slack).
    Prop::new(60).check(
        "s_max-balance",
        |r| {
            let g = 2 + r.below_usize(6);
            let b = 2 + r.below_usize(6);
            let sizes: Vec<f64> =
                (0..g * b).map(|_| 1.0 + r.f64() * 999.0).collect();
            (g, b, sizes)
        },
        |(g, b, sizes)| {
            let workers: Vec<WorkerView> = (0..*g)
                .map(|_| WorkerView { load: 0.0, free_slots: *b, active: vec![] })
                .collect();
            let waiting: Vec<WaitingView> = sizes
                .iter()
                .enumerate()
                .map(|(i, &s)| WaitingView { idx: i, prefill: s, arrival_step: 0 })
                .collect();
            let drift = [0.0];
            let ctx = AssignCtx {
                step: 0,
                batch_cap: *b,
                workers: &workers,
                waiting: &waiting,
                cum_drift: &drift,
            };
            let mut p = BfIo::with_horizon(0);
            let a = p.assign(&ctx, &mut Rng::new(3));
            let mut loads = vec![0.0; *g];
            for &(w, gi) in &a {
                loads[gi] += sizes[w];
            }
            let max = loads.iter().cloned().fold(f64::MIN, f64::max);
            let min = loads.iter().cloned().fold(f64::MAX, f64::min);
            let s_max = sizes.iter().cloned().fold(0.0, f64::max);
            if max - min <= 2.0 * s_max + 1e-9 {
                Ok(())
            } else {
                Err(format!("gap {} > 2·s_max {}", max - min, s_max))
            }
        },
    );
}

#[test]
fn prop_heuristic_within_smax_of_exact() {
    Prop::new(40).check(
        "heuristic-vs-exact",
        |r| {
            let g = 2 + r.below_usize(2);
            let n = 3 + r.below_usize(5);
            let caps: Vec<usize> = (0..g).map(|_| r.below_usize(3)).collect();
            let sizes: Vec<f64> =
                (0..n).map(|_| (1.0 + r.f64() * 100.0).round()).collect();
            let loads: Vec<f64> = (0..g).map(|_| (r.f64() * 100.0).round()).collect();
            (caps, sizes, loads)
        },
        |(caps, sizes, loads)| {
            let total_cap: usize = caps.iter().sum();
            let u = total_cap.min(sizes.len());
            if u == 0 {
                return Ok(());
            }
            let workers: Vec<WorkerView> = loads
                .iter()
                .zip(caps)
                .map(|(&l, &c)| WorkerView {
                    load: l,
                    free_slots: c,
                    active: if l > 0.0 {
                        vec![ActiveView::fresh(l, 100)]
                    } else {
                        vec![]
                    },
                })
                .collect();
            let waiting: Vec<WaitingView> = sizes
                .iter()
                .enumerate()
                .map(|(i, &s)| WaitingView { idx: i, prefill: s, arrival_step: 0 })
                .collect();
            let drift = [0.0];
            let ctx = AssignCtx {
                step: 0,
                batch_cap: 8,
                workers: &workers,
                waiting: &waiting,
                cum_drift: &drift,
            };
            let mut p = BfIo::new(BfIoConfig { pool_factor: 64, ..Default::default() });
            let a = p.assign(&ctx, &mut Rng::new(7));
            let mut after = loads.clone();
            for &(w, gi) in &a {
                after[gi] += sizes[w];
            }
            let j_heur = bfio_serve::metrics::imbalance(&after);

            let wl = WindowedLoads::from_views(&workers, &drift, 0, None);
            let sol = solve_exact(&wl, sizes, caps, u);
            let s_max = sizes.iter().cloned().fold(0.0, f64::max);
            if j_heur <= sol.j + s_max + 1e-6 {
                Ok(())
            } else {
                Err(format!("heuristic {} vs exact {} (s_max {})", j_heur, sol.j, s_max))
            }
        },
    );
}

#[test]
fn prop_exact_solution_feasible() {
    Prop::new(60).check(
        "exact-feasibility",
        |r| {
            let g = 2 + r.below_usize(2);
            let n = 2 + r.below_usize(5);
            let caps: Vec<usize> = (0..g).map(|_| r.below_usize(3)).collect();
            let sizes: Vec<f64> = (0..n).map(|_| 1.0 + r.f64() * 50.0).collect();
            (caps, sizes)
        },
        |(caps, sizes)| {
            let total: usize = caps.iter().sum();
            let u = total.min(sizes.len());
            let workers: Vec<WorkerView> = caps
                .iter()
                .map(|&c| WorkerView { load: 0.0, free_slots: c, active: vec![] })
                .collect();
            let drift = [0.0];
            let wl = WindowedLoads::from_views(&workers, &drift, 0, None);
            let sol = solve_exact(&wl, sizes, caps, u);
            let admitted = sol.placement.iter().filter(|p| p.is_some()).count();
            if admitted != u {
                return Err(format!("admitted {admitted} != u {u}"));
            }
            let mut used = vec![0usize; caps.len()];
            for p in sol.placement.iter().flatten() {
                used[*p] += 1;
            }
            for (g, (&usd, &cap)) in used.iter().zip(caps).enumerate() {
                if usd > cap {
                    return Err(format!("worker {g} over capacity"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_windowed_objective_eval_apply_consistent() {
    // eval() must exactly predict apply() for arbitrary move sequences.
    Prop::new(100).check(
        "eval-apply-consistency",
        |r| {
            let g = 2 + r.below_usize(6);
            let h = r.below_usize(12);
            let loads: Vec<(f64, u64)> = (0..g * 3)
                .map(|_| (1.0 + r.f64() * 100.0, 1 + r.below(20)))
                .collect();
            let moves: Vec<(usize, f64, f64)> = (0..8)
                .map(|_| {
                    (
                        r.below_usize(g),
                        r.f64() * 40.0 - 20.0,
                        if r.bernoulli(0.5) { 1.0 } else { 0.0 },
                    )
                })
                .collect();
            (g, h, loads, moves)
        },
        |(g, h, loads, moves)| {
            let workers: Vec<WorkerView> = (0..*g)
                .map(|gi| WorkerView {
                    load: 0.0,
                    free_slots: 1,
                    active: loads[gi * 3..gi * 3 + 3]
                        .iter()
                        .map(|&(l, r)| ActiveView::fresh(l, r))
                        .collect(),
                })
                .collect();
            let drift = Drift::Unit.cumulative(0, (*h).max(1));
            let mut wl = WindowedLoads::from_views(&workers, &drift, *h, None);
            for mv in moves {
                let before = wl.j();
                let dj = wl.eval(&[*mv]);
                wl.apply(&[*mv]);
                let after = wl.j();
                if (after - (before + dj)).abs() > 1e-6 * after.abs().max(1.0) {
                    return Err(format!(
                        "eval {} but J moved {} -> {}",
                        dj, before, after
                    ));
                }
            }
            Ok(())
        },
    );
}
