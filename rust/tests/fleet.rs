//! Fleet invariants and end-to-end coverage for the two-level routing
//! subsystem:
//!
//! * property suites (in the style of `prop_policies.rs`): every
//!   submitted request is admitted to exactly one replica and completes
//!   exactly once, with sticky worker placement inside that replica;
//! * the decomposition theorem of the round model: a fleet of R
//!   1.0-speed replicas under a work-conserving router is *exactly* R
//!   independent single-group simulations of the partitioned trace;
//! * lifecycle churn (drain / add / remove mid-trace) respects
//!   non-migratable state and loses nothing;
//! * the HTTP gateway serves `/v1/completions`, `/v0/workers`, and
//!   `/metrics` over a `FleetBackend` with R >= 2.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Duration;

use bfio_serve::config::SimConfig;
use bfio_serve::fleet::{
    run_fleet, FleetBackend, FleetBackendConfig, FleetConfig, FleetEvent,
    FleetResult, ReplicaState,
};
use bfio_serve::gateway::http as ghttp;
use bfio_serve::gateway::loadgen;
use bfio_serve::gateway::{Gateway, GatewayConfig};
use bfio_serve::sim::Simulator;
use bfio_serve::util::json::Json;
use bfio_serve::util::prop::Prop;
use bfio_serve::util::rng::Rng;
use bfio_serve::workload::{
    generate_trace, ArrivalProcess, Drift, GeometricSampler, Request,
};

fn trace_of(seed: u64, per_step: usize, backlog: usize, steps: u64) -> Vec<Request> {
    // decode capped so churn timing (drain → idle → removal) is certain
    let mut sampler = GeometricSampler::new(5, 80, 0.25);
    sampler.o_cap = 12;
    let arrivals = ArrivalProcess::Fixed { per_step, initial_backlog: backlog };
    let mut rng = Rng::new(seed);
    generate_trace(&sampler, &arrivals, steps, &mut rng)
}

fn recording(cfg: FleetConfig) -> FleetConfig {
    FleetConfig { record_completions: true, ..cfg }
}

// ---------------------------------------------------------------------
// Property: exactly-one-replica admission + sticky workers
// ---------------------------------------------------------------------

#[test]
fn prop_every_request_admitted_to_exactly_one_replica() {
    let routers = ["wrr", "low", "powd:2", "bfio2", "bfio2h"];
    Prop::new(25).check(
        "one-replica-admission",
        |r| {
            let replicas = 2 + r.below_usize(3);
            let g = 1 + r.below_usize(3);
            let b = 1 + r.below_usize(3);
            let seed = r.next_u64();
            let router = routers[r.below_usize(routers.len())];
            (replicas, g, b, seed, router)
        },
        |&(replicas, g, b, seed, router)| {
            let trace = trace_of(seed, 2, 10, 15);
            let cfg = recording(FleetConfig {
                seed,
                ..FleetConfig::uniform(replicas, g, b, "jsq")
            });
            let res = run_fleet(&cfg, router, &trace, &[])
                .map_err(|e| e.to_string())?;
            if res.completed as usize != trace.len() {
                return Err(format!(
                    "{router}: completed {} of {}",
                    res.completed,
                    trace.len()
                ));
            }
            let routed: u64 = res.per_replica.iter().map(|r| r.routed).sum();
            if routed as usize != trace.len() {
                return Err(format!("{router}: routed {routed}"));
            }
            // every trace id completes exactly once, on exactly one
            // replica, on a worker inside that replica's range
            let mut seen: HashMap<u64, (usize, usize)> = HashMap::new();
            for rep in &res.per_replica {
                if rep.admitted != rep.completed {
                    return Err(format!(
                        "replica {}: admitted {} != completed {}",
                        rep.id, rep.admitted, rep.completed
                    ));
                }
                for c in &rep.report.completions {
                    if c.worker >= g {
                        return Err(format!(
                            "worker {} out of range (g={g})",
                            c.worker
                        ));
                    }
                    if seen.insert(c.id, (rep.id, c.worker)).is_some() {
                        return Err(format!("id {} completed twice", c.id));
                    }
                }
            }
            if seen.len() != trace.len() {
                return Err(format!(
                    "{} distinct completions for {} requests",
                    seen.len(),
                    trace.len()
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Decomposition: uniform fleet == R independent single-group runs
// ---------------------------------------------------------------------

/// A fleet of R speed-1.0 replicas with a work-conserving router must
/// produce, per replica, exactly the run the offline `Simulator` (seed
/// `base + r`) produces on that replica's share of the trace: same
/// placements, clocks, imbalance, energy.  This pins the round model to
/// the single-group semantics — the fleet adds routing, nothing else.
#[test]
fn uniform_fleet_matches_independent_single_group_runs() {
    let base_seed = 11u64;
    let g = 2;
    let b = 3;
    let replicas = 3;
    let trace = trace_of(21, 3, 20, 25);
    let cfg = recording(FleetConfig {
        seed: base_seed,
        ..FleetConfig::uniform(replicas, g, b, "least")
    });
    let res = run_fleet(&cfg, "wrr", &trace, &[]).unwrap();
    assert_eq!(res.completed as usize, trace.len());

    let by_id: BTreeMap<u64, &Request> =
        trace.iter().map(|r| (r.id, r)).collect();
    for rep in &res.per_replica {
        // the replica's share, in original trace order
        let mut ids: Vec<u64> =
            rep.report.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        let sub: Vec<Request> =
            ids.iter().map(|id| by_id[id].clone()).collect();
        assert_eq!(sub.len() as u64, rep.completed);

        let sim_cfg = SimConfig {
            g,
            b,
            seed: base_seed + rep.id as u64,
            max_steps: 0,
            warmup_steps: 0,
            record_completions: true,
            ..SimConfig::default()
        };
        let solo = Simulator::new(sim_cfg)
            .run(&sub, &mut *bfio_serve::policies::by_name("least").unwrap());

        assert_eq!(solo.completed, rep.completed, "replica {}", rep.id);
        assert_eq!(solo.steps, rep.executed, "replica {}: steps", rep.id);
        let close = |a: f64, b: f64, what: &str| {
            let scale = 1.0_f64.max(a.abs()).max(b.abs());
            assert!(
                (a - b).abs() <= 1e-9 * scale,
                "replica {}: {what}: fleet {a:.17e} vs solo {b:.17e}",
                rep.id
            );
        };
        close(rep.clock_s, solo.report.wall_time_s, "clock");
        close(rep.report.avg_imbalance, solo.report.avg_imbalance, "imb");
        close(rep.report.total_energy_j, solo.report.total_energy_j, "energy");
        close(rep.report.tpot_s, solo.report.tpot_s, "tpot");

        let mut a = rep.report.completions.clone();
        let mut b2 = solo.report.completions.clone();
        a.sort_by_key(|c| c.id);
        b2.sort_by_key(|c| c.id);
        for (x, y) in a.iter().zip(&b2) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.worker, y.worker, "id {} placed differently", x.id);
            assert_eq!(x.tokens, y.tokens);
            close(x.arrival_clock, y.arrival_clock, "arrival_clock");
            close(x.admit_clock, y.admit_clock, "admit_clock");
            close(x.finish_clock, y.finish_clock, "finish_clock");
        }
    }
}

// ---------------------------------------------------------------------
// Lifecycle churn
// ---------------------------------------------------------------------

#[test]
fn churn_drain_add_remove_loses_nothing() {
    let trace = trace_of(31, 2, 8, 40);
    let cfg = recording(FleetConfig {
        seed: 5,
        ..FleetConfig::uniform(3, 2, 2, "jsq")
    });
    let events = vec![
        FleetEvent::Drain { round: 10, replica: 0 },
        FleetEvent::Add { round: 15, speed: 1.5 },
        FleetEvent::Remove { round: 20, replica: 1 },
    ];
    let res = run_fleet(&cfg, "low", &trace, &events).unwrap();
    assert_eq!(res.completed as usize, trace.len(), "churn loses nothing");
    assert_eq!(res.leftover_waiting, 0);
    assert_eq!(res.per_replica.len(), 4, "added replica reported");

    // drained replica 0: nothing routed after round 10 — every one of
    // its completions arrived at or before the drain round
    let r0 = &res.per_replica[0];
    assert_eq!(r0.state, ReplicaState::Draining { remove: false });
    for c in &r0.report.completions {
        let arrival = trace.iter().find(|t| t.id == c.id).unwrap().arrival_step;
        assert!(arrival <= 10, "id {} arrived at {arrival} > drain", c.id);
    }
    // removed replica 1 retired after finishing in place
    assert_eq!(res.per_replica[1].state, ReplicaState::Removed);
    for c in &res.per_replica[1].report.completions {
        let arrival = trace.iter().find(|t| t.id == c.id).unwrap().arrival_step;
        assert!(arrival <= 20, "id {} arrived past removal", c.id);
    }
    // the late-added replica (id 3, speed 1.5) picked up real work
    let added = &res.per_replica[3];
    assert_eq!(added.speed, 1.5);
    assert!(added.completed > 0, "added replica never used");
}

#[test]
fn heterogeneous_shapes_serve_the_trace_under_every_router() {
    // Asymmetric replicas (1x2, 3x2, 2x4) under each tier-1 router:
    // everything completes exactly once and the per-replica snapshots
    // report the configured shapes.
    let trace = trace_of(51, 3, 15, 25);
    for router in ALL_ROUTERS {
        let cfg = recording(FleetConfig {
            seed: 13,
            shapes: Some(vec![(1, 2), (3, 2), (2, 4)]),
            ..FleetConfig::uniform(3, 2, 2, "jsq")
        });
        let res = run_fleet(&cfg, router, &trace, &[]).unwrap();
        assert_eq!(
            res.completed as usize,
            trace.len(),
            "router {router} on asymmetric shapes"
        );
        assert_eq!(res.leftover_waiting, 0);
        let mut seen = HashMap::new();
        for rep in &res.per_replica {
            for c in &rep.report.completions {
                assert!(seen.insert(c.id, rep.id).is_none(), "id {} twice", c.id);
            }
        }
        assert_eq!(seen.len(), trace.len());
        // worker indices stay inside each replica's own G
        let gs = [1usize, 3, 2];
        for rep in &res.per_replica {
            for c in &rep.report.completions {
                assert!(
                    c.worker < gs[rep.id],
                    "router {router}: worker {} out of range for replica {}",
                    c.worker,
                    rep.id
                );
            }
        }
    }
}

#[test]
fn heterogeneous_speeds_shift_work_to_fast_replicas() {
    let trace = trace_of(41, 4, 40, 30);
    let cfg = FleetConfig {
        seed: 9,
        speeds: vec![1.0, 4.0],
        ..FleetConfig::uniform(2, 2, 4, "least")
    };
    let res = run_fleet(&cfg, "low", &trace, &[]).unwrap();
    assert_eq!(res.completed as usize, trace.len());
    let slow = &res.per_replica[0];
    let fast = &res.per_replica[1];
    assert!(
        fast.routed > slow.routed,
        "least-outstanding should favor the 4x replica: {} vs {}",
        fast.routed,
        slow.routed
    );
    // speed-aware routing keeps the virtual clocks far closer than the
    // 4x raw speed gap
    assert!(res.clock_ratio < 2.0, "clock ratio {}", res.clock_ratio);
}

// ---------------------------------------------------------------------
// Parallel ≡ serial parity (the `fleet_parity` CI gate)
// ---------------------------------------------------------------------

const ALL_ROUTERS: [&str; 5] = ["wrr", "low", "powd:2", "bfio2", "bfio2h"];

/// Every field of two `FleetResult`s must agree: integers and
/// placements exactly, floats to ≤1e-9 relative (replicas run the same
/// per-slot code whatever the thread count, so in practice the floats
/// are bit-identical too — the tolerance only absorbs a hypothetical
/// future reassociation).
fn assert_fleet_results_match(what: &str, a: &FleetResult, b: &FleetResult) {
    let close = |x: f64, y: f64, field: &str| {
        let scale = 1.0_f64.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= 1e-9 * scale,
            "{what}: {field}: serial {x:.17e} vs parallel {y:.17e}"
        );
    };
    assert_eq!(a.router, b.router, "{what}: router");
    assert_eq!(a.policy, b.policy, "{what}: policy");
    assert_eq!(a.rounds, b.rounds, "{what}: rounds");
    assert_eq!(a.steps, b.steps, "{what}: steps");
    assert_eq!(a.submitted, b.submitted, "{what}: submitted");
    assert_eq!(a.completed, b.completed, "{what}: completed");
    assert_eq!(a.leftover_waiting, b.leftover_waiting, "{what}: leftover");
    close(a.makespan_s, b.makespan_s, "makespan");
    close(a.clock_ratio, b.clock_ratio, "clock_ratio");
    close(a.energy_j, b.energy_j, "energy");
    close(a.avg_imbalance, b.avg_imbalance, "avg_imbalance");
    close(a.tpot_s, b.tpot_s, "tpot");
    close(a.mean_queue_wait_s, b.mean_queue_wait_s, "queue_wait");
    close(a.throughput_tps, b.throughput_tps, "throughput");
    close(a.total_tokens, b.total_tokens, "tokens");
    assert_eq!(a.per_replica.len(), b.per_replica.len(), "{what}: replicas");
    for (ra, rb) in a.per_replica.iter().zip(&b.per_replica) {
        let who = format!("{what}: replica {}", ra.id);
        assert_eq!(ra.id, rb.id, "{who}: id");
        assert_eq!(ra.state, rb.state, "{who}: state");
        assert_eq!(ra.routed, rb.routed, "{who}: routed");
        assert_eq!(ra.admitted, rb.admitted, "{who}: admitted");
        assert_eq!(ra.completed, rb.completed, "{who}: completed");
        assert_eq!(ra.executed, rb.executed, "{who}: executed");
        assert_eq!(ra.leftover_waiting, rb.leftover_waiting, "{who}: leftover");
        close(ra.clock_s, rb.clock_s, &format!("replica {} clock", ra.id));
        close(
            ra.report.avg_imbalance,
            rb.report.avg_imbalance,
            &format!("replica {} imbalance", ra.id),
        );
        close(
            ra.report.total_energy_j,
            rb.report.total_energy_j,
            &format!("replica {} energy", ra.id),
        );
        assert_eq!(
            ra.report.completions.len(),
            rb.report.completions.len(),
            "{who}: completion count"
        );
        for (ca, cb) in ra.report.completions.iter().zip(&rb.report.completions) {
            assert_eq!(ca.id, cb.id, "{who}: completion order");
            assert_eq!(ca.worker, cb.worker, "{who}: id {} placement", ca.id);
            assert_eq!(ca.tokens, cb.tokens, "{who}: id {} tokens", ca.id);
            close(ca.arrival_clock, cb.arrival_clock, "arrival_clock");
            close(ca.admit_clock, cb.admit_clock, "admit_clock");
            close(ca.finish_clock, cb.finish_clock, "finish_clock");
        }
    }
}

/// All five routers × {Unit, Cycle, Decay} drift, `threads ∈ {1, 2, 8}`:
/// the parallel round executor must reproduce the serial path exactly —
/// replicas own their policy/recorder/rng, so fan-out is a wall-clock
/// optimization, never a semantic one.
#[test]
fn fleet_parity_parallel_matches_serial_across_routers_and_drifts() {
    let drifts = [
        ("unit", Drift::Unit),
        ("cycle", Drift::Cycle(vec![2.0, 0.0, 1.0])),
        ("decay", Drift::Decay { d0: 1.5, rate: 0.8 }),
    ];
    let trace = trace_of(17, 3, 12, 20);
    for router in ALL_ROUTERS {
        for (dname, drift) in &drifts {
            let cfg = recording(FleetConfig {
                seed: 23,
                drift: drift.clone(),
                threads: 1,
                ..FleetConfig::uniform(3, 2, 2, "jsq")
            });
            let serial = run_fleet(&cfg, router, &trace, &[]).unwrap();
            assert_eq!(serial.completed as usize, trace.len(), "{router}/{dname}");
            for threads in [2usize, 8] {
                let pcfg = FleetConfig { threads, ..cfg.clone() };
                let par = run_fleet(&pcfg, router, &trace, &[]).unwrap();
                assert_fleet_results_match(
                    &format!("{router}/{dname}/threads={threads}"),
                    &serial,
                    &par,
                );
            }
        }
    }
}

/// Parity must survive the hard cases together: lifecycle churn
/// (drain / add / remove mid-trace), heterogeneous per-replica shapes,
/// an age-varying drift, and a lookahead tier-2 policy.
#[test]
fn fleet_parity_holds_under_churn_and_heterogeneous_shapes() {
    let trace = trace_of(61, 3, 10, 30);
    let events = vec![
        FleetEvent::Drain { round: 8, replica: 0 },
        FleetEvent::Add { round: 12, speed: 1.5 },
        FleetEvent::Remove { round: 18, replica: 1 },
    ];
    for router in ALL_ROUTERS {
        let cfg = recording(FleetConfig {
            seed: 31,
            drift: Drift::Cycle(vec![1.0, 2.0]),
            shapes: Some(vec![(1, 2), (3, 2), (2, 4)]),
            threads: 1,
            ..FleetConfig::uniform(3, 2, 2, "bfio:4")
        });
        let serial = run_fleet(&cfg, router, &trace, &events).unwrap();
        assert_eq!(
            serial.completed as usize,
            trace.len(),
            "{router}: churn loses nothing"
        );
        for threads in [2usize, 8] {
            let pcfg = FleetConfig { threads, ..cfg.clone() };
            let par = run_fleet(&pcfg, router, &trace, &events).unwrap();
            assert_fleet_results_match(
                &format!("{router}/churn+shapes/threads={threads}"),
                &serial,
                &par,
            );
        }
    }
}

// ---------------------------------------------------------------------
// Gateway over a fleet
// ---------------------------------------------------------------------

fn boot_fleet(router: &str, policy: &str) -> (Gateway, String) {
    let backend = FleetBackend::new(FleetBackendConfig {
        replicas: 2,
        g: 2,
        b: 2,
        policy: policy.to_string(),
        router: router.to_string(),
        step_delay: Duration::ZERO,
        batch_window: Duration::ZERO,
        ..FleetBackendConfig::default()
    })
    .unwrap();
    let gw = Gateway::spawn(
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 8,
            ..GatewayConfig::default()
        },
        Arc::new(backend),
    )
    .unwrap();
    let authority = gw.addr.to_string();
    (gw, authority)
}

#[test]
fn gateway_journal_endpoint_serves_replayable_jsonl() {
    let backend = FleetBackend::new(FleetBackendConfig {
        replicas: 2,
        g: 2,
        b: 2,
        policy: "bfio:8".to_string(),
        router: "low".to_string(),
        step_delay: Duration::ZERO,
        batch_window: Duration::ZERO,
        journal: true,
        ..FleetBackendConfig::default()
    })
    .unwrap();
    let gw = Gateway::spawn(
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 8,
            ..GatewayConfig::default()
        },
        Arc::new(backend),
    )
    .unwrap();
    let a = gw.addr.to_string();
    for i in 0..4 {
        let body = format!(r#"{{"prompt": [7, 8, {i}], "max_tokens": 4}}"#);
        let r = ghttp::http_call(&a, "POST", "/v1/completions", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
    }
    let r = ghttp::http_call(&a, "GET", "/v0/journal", None).unwrap();
    assert_eq!(r.status, 200);
    let body = r.body_str().unwrap();
    let header = Json::parse(body.lines().next().unwrap()).unwrap();
    assert_eq!(header.get("journal").and_then(Json::as_bool), Some(true));
    // The served document parses back into a journal carrying every
    // arrival the gateway admitted.
    let journal = bfio_serve::obs::Journal::from_jsonl(body).unwrap();
    let arrivals = journal
        .ring
        .events()
        .filter(|ev| ev.kind == bfio_serve::obs::journal::EV_ARRIVAL)
        .count();
    assert_eq!(arrivals, 4);
    assert!(journal.route_seq >= 4, "each arrival was routed");
    gw.shutdown();
}

#[test]
fn gateway_serves_completions_over_a_fleet() {
    let (gw, a) = boot_fleet("low", "bfio:8");
    for i in 0..6 {
        let body = format!(r#"{{"prompt": [7, 8, {i}], "max_tokens": 4}}"#);
        let r = ghttp::http_call(&a, "POST", "/v1/completions", Some(&body))
            .unwrap();
        assert_eq!(r.status, 200, "body: {}", r.body_str().unwrap_or(""));
        let v = Json::parse(r.body_str().unwrap()).unwrap();
        assert!(v
            .get("model")
            .unwrap()
            .as_str()
            .unwrap()
            .starts_with("fleet(2x2)/"));
        let worker = v
            .get("bfio")
            .unwrap()
            .get("worker")
            .unwrap()
            .as_usize()
            .unwrap();
        assert!(worker < 4, "global worker id over 2 replicas x 2 workers");
    }

    // /v0/workers: R·G workers with replica fields + a replicas array
    let r = ghttp::http_call(&a, "GET", "/v0/workers", None).unwrap();
    assert_eq!(r.status, 200);
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    let workers = v.get("workers").unwrap().as_arr().unwrap();
    assert_eq!(workers.len(), 4);
    for w in workers {
        assert!(w.get("replica").unwrap().as_usize().unwrap() < 2);
    }
    let replicas = v.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(replicas.len(), 2);
    let done: u64 = replicas
        .iter()
        .map(|r| r.get("completed").unwrap().as_u64().unwrap())
        .sum();
    assert_eq!(done, 6);
    assert!(replicas
        .iter()
        .all(|r| r.get("state").unwrap().as_str().unwrap() == "accepting"));

    // /metrics: per-replica labels on worker series + replica families
    let r = ghttp::http_call(&a, "GET", "/metrics", None).unwrap();
    assert_eq!(r.status, 200);
    let text = r.body_str().unwrap();
    assert!(text.contains("bfio_worker_load{replica=\"0\",worker=\"0\"}"));
    assert!(text.contains("bfio_worker_load{replica=\"1\",worker=\"2\"}"));
    assert!(text.contains("# TYPE bfio_replica_load gauge"));
    assert!(text.contains("bfio_replica_completed_total{replica=\"0\"}"));
    assert!(text.contains("bfio_replica_speed{replica=\"1\",state=\"accepting\"}"));
    assert_eq!(
        loadgen::prom_value(text, "bfio_requests_total"),
        Some(6.0)
    );
    assert_eq!(loadgen::prom_value(text, "bfio_tokens_total"), Some(24.0));
    assert!(loadgen::prom_value(text, "bfio_energy_joules").unwrap() > 0.0);
    gw.shutdown();
}

#[test]
fn concurrent_gateway_fleet_requests_spread_over_replicas() {
    let (gw, a) = boot_fleet("wrr", "jsq");
    let n = 10usize;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let a = a.clone();
            std::thread::spawn(move || {
                let body =
                    format!(r#"{{"prompt": [1, 2, {i}], "max_tokens": 3}}"#);
                let r =
                    ghttp::http_call(&a, "POST", "/v1/completions", Some(&body))
                        .unwrap();
                assert_eq!(r.status, 200);
                let v = Json::parse(r.body_str().unwrap()).unwrap();
                v.get("bfio")
                    .unwrap()
                    .get("request_id")
                    .unwrap()
                    .as_u64()
                    .unwrap()
            })
        })
        .collect();
    let mut ids: Vec<u64> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "request ids unique");

    let r = ghttp::http_call(&a, "GET", "/v0/workers", None).unwrap();
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    let per: u64 = v
        .get("workers")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|w| w.get("completed").unwrap().as_u64().unwrap())
        .sum();
    assert_eq!(per, n as u64);
    gw.shutdown();
}
