//! Observability-layer integration tests: DDSketch-vs-exact quantile
//! parity on realistic workload shapes (Zipf prompt lengths,
//! BurstGPT-like lognormal latencies) including the merge path, plus an
//! exposition-lint roundtrip over a real rendered report.

use bfio_serve::config::SimConfig;
use bfio_serve::metrics::prometheus::{lint, render_report, PromWriter};
use bfio_serve::obs::sketch::{seconds_buckets, token_buckets, DEFAULT_ALPHA};
use bfio_serve::obs::QuantileSketch;
use bfio_serve::sim::Simulator;
use bfio_serve::util::rng::{Rng, Zipf};
use bfio_serve::util::stats;
use bfio_serve::workload::adversarial::overloaded_trace;
use bfio_serve::workload::longbench::LongBenchLike;

/// Assert every checked quantile of `sk` is within the DDSketch
/// relative-error guarantee of the exact sample quantile.  The exact
/// side interpolates between order statistics, so allow the guarantee
/// `alpha` plus the gap one rank can contribute at these sample sizes.
fn assert_parity(sk: &QuantileSketch, xs: &[f64], label: &str) {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    for &q in &[0.01, 0.10, 0.25, 0.50, 0.75, 0.90, 0.99, 0.999] {
        let got = sk.quantile(q).expect("non-empty sketch");
        let want = stats::percentile_sorted(&sorted, q * 100.0);
        let tol = 2.5 * DEFAULT_ALPHA * want.abs() + 1e-12;
        assert!(
            (got - want).abs() <= tol,
            "{label}: q={q} sketch {got} vs exact {want} (tol {tol})"
        );
    }
    // q=0 / q=1 are exact by construction.
    assert_eq!(sk.quantile(0.0), Some(sorted[0]));
    assert_eq!(sk.quantile(1.0), Some(*sorted.last().unwrap()));
    assert_eq!(sk.count(), xs.len() as u64);
}

#[test]
fn sketch_matches_exact_on_zipf_shaped_samples() {
    // Zipf prompt lengths — the heavy-tailed shape prompt-length
    // distributions take in the paper's workloads.
    let z = Zipf::new(20_000, 1.1);
    let mut rng = Rng::new(42);
    let xs: Vec<f64> = (0..50_000).map(|_| z.sample(&mut rng) as f64).collect();
    let mut sk = QuantileSketch::default();
    for &x in &xs {
        sk.insert(x);
    }
    assert_parity(&sk, &xs, "zipf");
}

#[test]
fn sketch_matches_exact_on_burstgpt_like_latencies() {
    // Lognormal virtual latencies, the BurstGPT-like TTFT/TPOT shape:
    // median ~135 ms with a long right tail.
    let mut rng = Rng::new(7);
    let xs: Vec<f64> = (0..50_000).map(|_| rng.lognormal(-2.0, 1.0)).collect();
    let mut sk = QuantileSketch::default();
    for &x in &xs {
        sk.insert(x);
    }
    assert_parity(&sk, &xs, "lognormal");
}

#[test]
fn sharded_merge_is_exact_bucket_addition() {
    // Per-replica sketches merged fleet-side must answer exactly like
    // one sketch that saw every sample: merge adds bucket counts, so
    // the results are bit-identical, not merely within tolerance.
    let mut rng = Rng::new(19);
    let xs: Vec<f64> = (0..40_000).map(|_| rng.lognormal(-1.5, 1.3)).collect();
    let mut whole = QuantileSketch::default();
    let mut shards: Vec<QuantileSketch> = (0..8).map(|_| QuantileSketch::default()).collect();
    for (i, &x) in xs.iter().enumerate() {
        whole.insert(x);
        shards[i % 8].insert(x);
    }
    let mut merged = QuantileSketch::default();
    for sh in &shards {
        merged.merge(sh);
    }
    assert_eq!(merged.count(), whole.count());
    assert_eq!(merged.min(), whole.min());
    assert_eq!(merged.max(), whole.max());
    for &q in &[0.0, 0.01, 0.5, 0.9, 0.99, 0.999, 1.0] {
        assert_eq!(merged.quantile(q), whole.quantile(q), "q={q}");
    }
    assert_parity(&merged, &xs, "merged");
}

#[test]
fn rendered_report_exposition_passes_lint() {
    // End-to-end through the real pipeline: run the simulator on an
    // overloaded LongBench-like trace, render the report exposition
    // (histogram families backed by the live sketches included), and
    // hold it to the strict structural linter.
    let sampler = LongBenchLike::paper();
    let mut rng = Rng::new(23);
    let trace = overloaded_trace(&sampler, 4, 8, 80, 3.0, &mut rng);
    let cfg = SimConfig {
        g: 4,
        b: 8,
        max_steps: 80,
        warmup_steps: 16,
        seed: 23,
        ..SimConfig::default()
    };
    let mut policy = bfio_serve::policies::by_name("bfio:8").unwrap();
    let res = Simulator::new(cfg).run(&trace, policy.as_mut());
    assert!(res.completed > 0);
    assert!(res.report.obs.ttft.count() > 0, "sketches must be fed");
    assert!((0.0..=1.0).contains(&res.report.slo_goodput));
    let text = render_report(&res.report, "bfio:8");
    lint(&text).expect("rendered exposition must lint clean");

    // The histogram renderer over the run's live sketches: bucket lines
    // must be cumulative, le-labelled, +Inf == _count — lint checks all
    // of it structurally, then we spot-check the counts semantically.
    let mut w = PromWriter::new();
    let labels: [(&str, &str); 1] = [("policy", "bfio:8")];
    w.histogram(
        "bfio_ttft_seconds",
        "Time to first token per completion.",
        &labels,
        &res.report.obs.ttft,
        seconds_buckets(),
    );
    w.histogram(
        "bfio_step_imbalance_tokens",
        "Per-step instantaneous imbalance (Eq. 2).",
        &labels,
        &res.report.obs.imbalance,
        token_buckets(),
    );
    let text = w.finish();
    lint(&text).expect("histogram exposition must lint clean");
    assert!(text.contains("bfio_ttft_seconds_bucket"));
    assert!(text.contains("le=\"+Inf\""));
    assert!(text.contains(&format!(
        "bfio_ttft_seconds_count{{policy=\"bfio:8\"}} {}",
        res.report.obs.ttft.count()
    )));
}
