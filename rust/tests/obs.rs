//! Observability-layer integration tests: DDSketch-vs-exact quantile
//! parity on realistic workload shapes (Zipf prompt lengths,
//! BurstGPT-like lognormal latencies) including the merge path, an
//! exposition-lint roundtrip over a real rendered report, and the
//! PR-8 imbalance observatory: straggler-attribution conservation
//! under churn + faults, the regret-zero invariant for exact routers,
//! and the windowed series ring's bounds/eviction/merge contract.

use bfio_serve::config::SimConfig;
use bfio_serve::fleet::{
    run_fleet, run_fleet_faulted, FaultPlan, FleetConfig, FleetEvent,
};
use bfio_serve::metrics::prometheus::{lint, render_report, PromWriter};
use bfio_serve::obs::series::{
    ReplicaPoint, SeriesRing, SeriesTotals, HEALTH_HEALTHY,
};
use bfio_serve::obs::sketch::{seconds_buckets, token_buckets, DEFAULT_ALPHA};
use bfio_serve::obs::QuantileSketch;
use bfio_serve::sim::Simulator;
use bfio_serve::util::json::Json;
use bfio_serve::util::rng::{Rng, Zipf};
use bfio_serve::util::stats;
use bfio_serve::workload::adversarial::overloaded_trace;
use bfio_serve::workload::longbench::LongBenchLike;

/// Assert every checked quantile of `sk` is within the DDSketch
/// relative-error guarantee of the exact sample quantile.  The exact
/// side interpolates between order statistics, so allow the guarantee
/// `alpha` plus the gap one rank can contribute at these sample sizes.
fn assert_parity(sk: &QuantileSketch, xs: &[f64], label: &str) {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    for &q in &[0.01, 0.10, 0.25, 0.50, 0.75, 0.90, 0.99, 0.999] {
        let got = sk.quantile(q).expect("non-empty sketch");
        let want = stats::percentile_sorted(&sorted, q * 100.0);
        let tol = 2.5 * DEFAULT_ALPHA * want.abs() + 1e-12;
        assert!(
            (got - want).abs() <= tol,
            "{label}: q={q} sketch {got} vs exact {want} (tol {tol})"
        );
    }
    // q=0 / q=1 are exact by construction.
    assert_eq!(sk.quantile(0.0), Some(sorted[0]));
    assert_eq!(sk.quantile(1.0), Some(*sorted.last().unwrap()));
    assert_eq!(sk.count(), xs.len() as u64);
}

#[test]
fn sketch_matches_exact_on_zipf_shaped_samples() {
    // Zipf prompt lengths — the heavy-tailed shape prompt-length
    // distributions take in the paper's workloads.
    let z = Zipf::new(20_000, 1.1);
    let mut rng = Rng::new(42);
    let xs: Vec<f64> = (0..50_000).map(|_| z.sample(&mut rng) as f64).collect();
    let mut sk = QuantileSketch::default();
    for &x in &xs {
        sk.insert(x);
    }
    assert_parity(&sk, &xs, "zipf");
}

#[test]
fn sketch_matches_exact_on_burstgpt_like_latencies() {
    // Lognormal virtual latencies, the BurstGPT-like TTFT/TPOT shape:
    // median ~135 ms with a long right tail.
    let mut rng = Rng::new(7);
    let xs: Vec<f64> = (0..50_000).map(|_| rng.lognormal(-2.0, 1.0)).collect();
    let mut sk = QuantileSketch::default();
    for &x in &xs {
        sk.insert(x);
    }
    assert_parity(&sk, &xs, "lognormal");
}

#[test]
fn sharded_merge_is_exact_bucket_addition() {
    // Per-replica sketches merged fleet-side must answer exactly like
    // one sketch that saw every sample: merge adds bucket counts, so
    // the results are bit-identical, not merely within tolerance.
    let mut rng = Rng::new(19);
    let xs: Vec<f64> = (0..40_000).map(|_| rng.lognormal(-1.5, 1.3)).collect();
    let mut whole = QuantileSketch::default();
    let mut shards: Vec<QuantileSketch> = (0..8).map(|_| QuantileSketch::default()).collect();
    for (i, &x) in xs.iter().enumerate() {
        whole.insert(x);
        shards[i % 8].insert(x);
    }
    let mut merged = QuantileSketch::default();
    for sh in &shards {
        merged.merge(sh);
    }
    assert_eq!(merged.count(), whole.count());
    assert_eq!(merged.min(), whole.min());
    assert_eq!(merged.max(), whole.max());
    for &q in &[0.0, 0.01, 0.5, 0.9, 0.99, 0.999, 1.0] {
        assert_eq!(merged.quantile(q), whole.quantile(q), "q={q}");
    }
    assert_parity(&merged, &xs, "merged");
}

#[test]
fn rendered_report_exposition_passes_lint() {
    // End-to-end through the real pipeline: run the simulator on an
    // overloaded LongBench-like trace, render the report exposition
    // (histogram families backed by the live sketches included), and
    // hold it to the strict structural linter.
    let sampler = LongBenchLike::paper();
    let mut rng = Rng::new(23);
    let trace = overloaded_trace(&sampler, 4, 8, 80, 3.0, &mut rng);
    let cfg = SimConfig {
        g: 4,
        b: 8,
        max_steps: 80,
        warmup_steps: 16,
        seed: 23,
        ..SimConfig::default()
    };
    let mut policy = bfio_serve::policies::by_name("bfio:8").unwrap();
    let res = Simulator::new(cfg).run(&trace, policy.as_mut());
    assert!(res.completed > 0);
    assert!(res.report.obs.ttft.count() > 0, "sketches must be fed");
    assert!((0.0..=1.0).contains(&res.report.slo_goodput));
    let text = render_report(&res.report, "bfio:8");
    lint(&text).expect("rendered exposition must lint clean");

    // The histogram renderer over the run's live sketches: bucket lines
    // must be cumulative, le-labelled, +Inf == _count — lint checks all
    // of it structurally, then we spot-check the counts semantically.
    let mut w = PromWriter::new();
    let labels: [(&str, &str); 1] = [("policy", "bfio:8")];
    w.histogram(
        "bfio_ttft_seconds",
        "Time to first token per completion.",
        &labels,
        &res.report.obs.ttft,
        seconds_buckets(),
    );
    w.histogram(
        "bfio_step_imbalance_tokens",
        "Per-step instantaneous imbalance (Eq. 2).",
        &labels,
        &res.report.obs.imbalance,
        token_buckets(),
    );
    let text = w.finish();
    lint(&text).expect("histogram exposition must lint clean");
    assert!(text.contains("bfio_ttft_seconds_bucket"));
    assert!(text.contains("le=\"+Inf\""));
    assert!(text.contains(&format!(
        "bfio_ttft_seconds_count{{policy=\"bfio:8\"}} {}",
        res.report.obs.ttft.count()
    )));
}

#[test]
fn attribution_conserves_fleet_waste_under_churn_and_faults() {
    // The hardest case the ledger must survive: an overloaded trace on
    // a fleet that crashes, recovers, scales out, and drains mid-run.
    // Every barrier step charges its Theorem-4 `idle + correction`
    // delta to exactly one gating worker, so the attributed waste must
    // telescope back to the recorders' accumulators to ≤ 1e-9.
    let sampler = LongBenchLike::paper();
    let mut rng = Rng::new(31);
    let trace = overloaded_trace(&sampler, 6, 2, 120, 3.0, &mut rng);
    let cfg = FleetConfig {
        seed: 31,
        ..FleetConfig::uniform(3, 2, 2, "bfio:8")
    };
    let events = [
        FleetEvent::Add { round: 25, speed: 0.8 },
        FleetEvent::Drain { round: 60, replica: 2 },
    ];
    let plan = FaultPlan::parse("crash@20:r1,recover@50:r1").unwrap();
    let res = run_fleet_faulted(
        &cfg,
        "bfio2",
        &trace,
        &events,
        None,
        Some(&plan),
    )
    .unwrap();
    assert!(res.completed > 0, "run must make progress");
    assert_eq!(res.crashes, 1, "the planned crash must fire");
    assert_eq!(res.recoveries, 1, "the planned recovery must fire");

    let mut fleet_waste = 0.0f64;
    let mut fleet_attr = 0.0f64;
    for r in &res.per_replica {
        let waste = r.report.energy_idle_j + r.report.energy_correction_j;
        let tol = 1e-9 * 1.0f64.max(waste.abs());
        assert!(
            (r.attributed_waste_j - waste).abs() <= tol,
            "replica {}: attributed {:.17e} vs accumulator {:.17e}",
            r.id,
            r.attributed_waste_j,
            waste
        );
        // Every executed barrier step names exactly one gating worker.
        assert_eq!(
            r.gate_counts.iter().sum::<u64>(),
            r.executed,
            "replica {}: gates must count barrier steps",
            r.id
        );
        fleet_waste += waste;
        fleet_attr += r.attributed_waste_j;
    }
    assert!(
        fleet_attr > 0.0,
        "an overloaded run with churn must show nonzero waste"
    );
    let tol = 1e-9 * 1.0f64.max(fleet_waste.abs());
    assert!(
        (res.attributed_waste_j - fleet_attr).abs() <= tol,
        "fleet total {:.17e} vs summed replicas {:.17e}",
        res.attributed_waste_j,
        fleet_attr
    );
    assert!(
        (res.attributed_waste_j - fleet_waste).abs() <= tol,
        "fleet conservation: attributed {:.17e} vs Theorem-4 {:.17e}",
        res.attributed_waste_j,
        fleet_waste
    );
}

#[test]
fn exact_router_has_zero_regret_on_homogeneous_healthy_fleet() {
    // `bfio2` scores every replica with the exact cost model it routes
    // by, so on a homogeneous healthy fleet the audit's
    // `chosen − best` must be identically zero — any positive regret
    // here is a routing bug, not noise.
    let sampler = LongBenchLike::paper();
    let mut rng = Rng::new(47);
    let trace = overloaded_trace(&sampler, 8, 4, 100, 2.0, &mut rng);
    let cfg = FleetConfig {
        seed: 47,
        ..FleetConfig::uniform(4, 2, 4, "bfio:8")
    };
    let res = run_fleet(&cfg, "bfio2", &trace, &[]).unwrap();
    assert!(res.completed > 0, "run must make progress");
    assert!(res.regret.decisions > 0, "decisions must be counted");
    assert_eq!(
        res.regret.audited, res.regret.decisions,
        "a scoring router must expose a cost for every decision"
    );
    assert_eq!(
        res.regret.cumulative(),
        0.0,
        "exact router regret must be identically zero"
    );
    assert_eq!(res.regret.max_regret, 0.0, "no single decision regrets");

    // Contrast: a blind router takes decisions it cannot audit — the
    // counters must say so instead of inventing zero-regret claims.
    let blind = run_fleet(&cfg, "wrr", &trace, &[]).unwrap();
    assert!(blind.regret.decisions > 0);
    assert_eq!(
        blind.regret.audited, 0,
        "wrr exposes no cost model, so nothing is audited"
    );
}

#[test]
fn series_ring_bounds_eviction_and_merge() {
    // Bounds + oldest-first eviction: 20 windows into an 8-slot ring.
    let mut ring = SeriesRing::new(4, 8);
    assert!(ring.is_empty());
    assert!(!ring.due(3) && ring.due(4), "window-4 ring closes at 4k");
    let mut cum = SeriesTotals::default();
    for w in 1..=20u64 {
        cum.arrivals += 10;
        cum.completions += 9;
        cum.energy_j += 5.0;
        cum.useful_j += 3.0;
        cum.idle_j += 1.5;
        cum.correction_j += 0.5;
        let reps = ring.record(w * 4, w as f64, cum, 2.0, 0.1, 0.9);
        reps.push(ReplicaPoint {
            id: 0,
            health: HEALTH_HEALTHY,
            penalty: 1.0,
            gate_share: 1.0,
            load: 0.5,
        });
        assert!(ring.len() <= ring.capacity(), "ring must stay bounded");
    }
    assert_eq!(ring.len(), 8, "full ring holds exactly `cap` points");
    let rounds: Vec<u64> = ring.points().map(|p| p.round).collect();
    assert_eq!(
        rounds,
        (13..=20).map(|w| w * 4).collect::<Vec<_>>(),
        "eviction is oldest-first"
    );
    for p in ring.points() {
        // The ring stores per-window deltas, never cumulative totals.
        assert_eq!(p.arrivals, 10);
        assert_eq!(p.completions, 9);
        assert!((p.energy_j - 5.0).abs() < 1e-12);
        assert!((p.idle_j - 1.5).abs() < 1e-12);
        assert_eq!(p.replicas.len(), 1);
    }

    // The gateway's publish mirror: exact copy, version-gated.
    let mut mirror = SeriesRing::new(4, 8);
    mirror.copy_from(&ring);
    assert_eq!(mirror.version(), ring.version());
    assert_eq!(mirror.len(), ring.len());
    for (a, b) in mirror.points().zip(ring.points()) {
        assert_eq!(a, b, "mirror must be field-exact");
    }

    // Shard merge over aligned windows: additive fields add exactly,
    // the straggler gap maxes, goodput is completion-weighted.
    let mut a = SeriesRing::new(4, 16);
    let mut b = SeriesRing::new(4, 16);
    let mut ca = SeriesTotals::default();
    let mut cb = SeriesTotals::default();
    for w in 1..=6u64 {
        ca.arrivals += 4;
        ca.completions += 3;
        ca.energy_j += 2.0;
        cb.arrivals += 6;
        cb.completions += 5;
        cb.energy_j += 3.0;
        a.record(w * 4, w as f64, ca, 1.0, 0.05, 0.8);
        b.record(w * 4, w as f64, cb, 2.0, 0.20, 1.0);
    }
    a.merge_aligned(&b);
    assert_eq!(a.len(), 6, "aligned rounds merge in place, not append");
    for p in a.points() {
        assert_eq!(p.arrivals, 10);
        assert_eq!(p.completions, 8);
        assert!((p.energy_j - 5.0).abs() < 1e-12);
        assert!((p.imbalance - 3.0).abs() < 1e-12, "Eq. 2 terms add");
        assert!((p.straggler_gap_s - 0.20).abs() < 1e-12, "gap maxes");
        let want = (0.8 * 3.0 + 1.0 * 5.0) / 8.0;
        assert!(
            (p.goodput - want).abs() < 1e-12,
            "goodput is completion-weighted: {} vs {want}",
            p.goodput
        );
    }

    // The `/v0/series` document honours `last` and parses cleanly.
    let doc = ring.to_json(3);
    let parsed = Json::parse(&doc).expect("series JSON must parse");
    assert_eq!(parsed.get("len").and_then(Json::as_f64), Some(8.0));
    let pts = parsed
        .get("points")
        .and_then(Json::as_arr)
        .expect("points array");
    assert_eq!(pts.len(), 3, "`last` bounds the document");
    assert_eq!(pts[2].get("round").and_then(Json::as_f64), Some(80.0));
}
