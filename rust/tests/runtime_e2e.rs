//! End-to-end runtime integration: the full jax → HLO text → PJRT path,
//! plus the live coordinator over real model execution.  These tests
//! skip (with a notice) when `make artifacts` hasn't been run.

use std::path::{Path, PathBuf};

use bfio_serve::coordinator::{serve, CoordinatorConfig, ServeRequest};
use bfio_serve::runtime::Runtime;
use bfio_serve::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    if dir.join("meta.json").exists() {
        Some(dir.to_path_buf())
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn golden_cross_language_verification() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let err = rt.verify_golden().unwrap();
    assert!(err.is_finite());
}

#[test]
fn greedy_decoding_is_deterministic_across_runtimes() {
    let Some(dir) = artifacts() else { return };
    let run = || {
        let mut rt = Runtime::load(&dir).unwrap();
        let golden = rt.meta.golden.clone();
        let (_, mut state) = rt.prefill_batch(&golden.prompt, golden.kv_capacity).unwrap();
        let mut tokens = golden.next_tokens.clone();
        let mut out = Vec::new();
        for _ in 0..6 {
            let logits = rt.decode_step(&mut state, &tokens).unwrap();
            tokens = logits
                .chunks_exact(rt.meta.vocab)
                .map(|row| {
                    row.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .unwrap()
                        .0 as i32
                })
                .collect();
            out.push(tokens.clone());
        }
        out
    };
    assert_eq!(run(), run());
}

#[test]
fn coordinator_policies_serve_identical_request_sets() {
    let Some(dir) = artifacts() else { return };
    let mut rng = Rng::new(41);
    let requests: Vec<ServeRequest> = (0..8)
        .map(|i| ServeRequest {
            id: i,
            prompt: (0..3 + rng.below_usize(4)).map(|_| rng.below(64) as i32).collect(),
            max_new_tokens: 1 + rng.below(6) as u32,
        })
        .collect();
    for policy in ["fcfs", "jsq", "bfio:4"] {
        let cfg = CoordinatorConfig {
            artifacts_dir: dir.clone(),
            workers: 2,
            policy: policy.into(),
            max_steps: 5_000,
            seed: 2,
        };
        let rep = serve(&cfg, &requests).unwrap();
        assert_eq!(rep.served.len(), requests.len(), "{policy}");
        for s in &rep.served {
            let want = requests.iter().find(|r| r.id == s.id).unwrap();
            assert_eq!(s.generated, want.max_new_tokens, "{policy} req {}", s.id);
        }
        assert!(rep.steps > 0 && rep.wall_s > 0.0);
    }
}

#[test]
fn single_worker_coordinator_works() {
    let Some(dir) = artifacts() else { return };
    let cfg = CoordinatorConfig {
        artifacts_dir: dir,
        workers: 1,
        policy: "fcfs".into(),
        max_steps: 5_000,
        seed: 3,
    };
    let requests = vec![ServeRequest { id: 0, prompt: vec![1, 2], max_new_tokens: 3 }];
    let rep = serve(&cfg, &requests).unwrap();
    assert_eq!(rep.served.len(), 1);
    assert_eq!(rep.served[0].generated, 3);
    // With one worker there is never barrier idle.
    assert!(rep.mean_idle_fraction.abs() < 1e-9);
}
