//! Chaos coverage for the fault-injection subsystem:
//!
//! * a zero-fault plan is bit-identical to the fault-free driver, per
//!   router, across round-execution thread counts {1, 2, 8};
//! * property suite: random seeded fault schedules conserve work
//!   (`completed + shed == submitted`, nothing stranded) and stay
//!   bit-identical between serial and parallel round execution;
//! * explicit crash → recover loses nothing: crash-lost requests are
//!   requeued exactly once and the replica probes back to Healthy;
//! * fail-slow stalls are detected (Suspect) and the routers shift
//!   work off the slow replica;
//! * lifecycle drain racing a crash re-routes only to non-Down
//!   replicas (regression for the re-offer path);
//! * the gateway degrades gracefully when the backend sheds: bounded
//!   retries, then a well-formed 503 with `Retry-After` and the
//!   retry/shed counters visible in `/metrics`.

use std::sync::Arc;

use anyhow::bail;
use bfio_serve::fleet::{
    run_fleet, run_fleet_faulted, FaultPlan, FleetConfig, FleetEvent,
    FleetResult, ReplicaHealth,
};
use bfio_serve::gateway::backend::{
    Backend, BackendStats, Completion, CompletionRequest, WorkerStatus,
};
use bfio_serve::gateway::http as ghttp;
use bfio_serve::gateway::{Gateway, GatewayConfig};
use bfio_serve::util::json::Json;
use bfio_serve::util::prop::Prop;
use bfio_serve::util::rng::Rng;
use bfio_serve::workload::{
    generate_trace, ArrivalProcess, GeometricSampler, Request,
};

fn trace_of(seed: u64, per_step: usize, backlog: usize, steps: u64) -> Vec<Request> {
    let mut sampler = GeometricSampler::new(5, 80, 0.25);
    sampler.o_cap = 12;
    let arrivals = ArrivalProcess::Fixed { per_step, initial_backlog: backlog };
    let mut rng = Rng::new(seed);
    generate_trace(&sampler, &arrivals, steps, &mut rng)
}

fn cfg_of(replicas: usize, seed: u64, threads: usize) -> FleetConfig {
    FleetConfig {
        seed,
        threads,
        ..FleetConfig::uniform(replicas, 2, 2, "bfio:8")
    }
}

/// Field-by-field equality for two runs that must be deterministically
/// identical (same house tolerance as `tests/fleet.rs`, plus the fault
/// tallies).
fn assert_same(what: &str, a: &FleetResult, b: &FleetResult) {
    let close = |x: f64, y: f64, field: &str| {
        let scale = 1.0_f64.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= 1e-9 * scale,
            "{what}: {field}: {x:.17e} vs {y:.17e}"
        );
    };
    assert_eq!(a.rounds, b.rounds, "{what}: rounds");
    assert_eq!(a.steps, b.steps, "{what}: steps");
    assert_eq!(a.submitted, b.submitted, "{what}: submitted");
    assert_eq!(a.completed, b.completed, "{what}: completed");
    assert_eq!(a.leftover_waiting, b.leftover_waiting, "{what}: leftover");
    assert_eq!(a.crashes, b.crashes, "{what}: crashes");
    assert_eq!(a.stalls, b.stalls, "{what}: stalls");
    assert_eq!(a.recoveries, b.recoveries, "{what}: recoveries");
    assert_eq!(a.requeued, b.requeued, "{what}: requeued");
    assert_eq!(a.shed, b.shed, "{what}: shed");
    close(a.makespan_s, b.makespan_s, "makespan");
    close(a.energy_j, b.energy_j, "energy");
    close(a.tpot_s, b.tpot_s, "tpot");
    close(a.total_tokens, b.total_tokens, "tokens");
    close(a.slo_goodput, b.slo_goodput, "slo_goodput");
    assert_eq!(a.per_replica.len(), b.per_replica.len(), "{what}: replicas");
    for (ra, rb) in a.per_replica.iter().zip(&b.per_replica) {
        let who = format!("{what}: replica {}", ra.id);
        assert_eq!(ra.state, rb.state, "{who}: state");
        assert_eq!(ra.health, rb.health, "{who}: health");
        assert_eq!(ra.routed, rb.routed, "{who}: routed");
        assert_eq!(ra.completed, rb.completed, "{who}: completed");
        assert_eq!(ra.leftover_waiting, rb.leftover_waiting, "{who}: leftover");
        close(ra.clock_s, rb.clock_s, &format!("replica {} clock", ra.id));
    }
}

const ALL_ROUTERS: [&str; 5] = ["wrr", "low", "powd:2", "bfio2", "bfio2h"];

// ---------------------------------------------------------------------
// Zero-fault plan == fault-free driver, bit-identical, any thread count
// ---------------------------------------------------------------------

#[test]
fn empty_fault_plan_is_identical_to_fault_free_run() {
    let trace = trace_of(11, 2, 12, 30);
    let plan = FaultPlan::default();
    for router in ALL_ROUTERS {
        let base = run_fleet(&cfg_of(3, 11, 1), router, &trace, &[]).unwrap();
        assert_eq!(
            base.crashes + base.stalls + base.recoveries + base.requeued + base.shed,
            0,
            "{router}: fault-free run tallied faults"
        );
        for threads in [1usize, 2, 8] {
            let res = run_fleet_faulted(
                &cfg_of(3, 11, threads),
                router,
                &trace,
                &[],
                None,
                Some(&plan),
            )
            .unwrap();
            assert_same(&format!("{router}/t{threads}"), &base, &res);
        }
    }
}

// ---------------------------------------------------------------------
// Property: random schedules conserve work + serial/parallel parity
// ---------------------------------------------------------------------

#[test]
fn prop_chaos_conserves_work_and_matches_across_threads() {
    Prop::new(12).check(
        "chaos-conservation",
        |r| {
            let replicas = 3 + r.below_usize(3);
            let rate = 0.02 + 0.02 * r.below(5) as f64;
            let seed = r.next_u64();
            let router = ALL_ROUTERS[r.below_usize(ALL_ROUTERS.len())];
            (replicas, rate, seed, router)
        },
        |&(replicas, rate, seed, router)| {
            let trace = trace_of(seed, 2, 10, 25);
            let plan = FaultPlan::random(rate, seed);
            let run = |threads: usize| {
                run_fleet_faulted(
                    &cfg_of(replicas, seed, threads),
                    router,
                    &trace,
                    &[],
                    None,
                    Some(&plan),
                )
                .map_err(|e| e.to_string())
            };
            let serial = run(1)?;
            let parallel = run(8)?;
            assert_same(&format!("{router} rate {rate}"), &serial, &parallel);
            if serial.completed + serial.shed != serial.submitted {
                return Err(format!(
                    "{router}: completed {} + shed {} != submitted {}",
                    serial.completed, serial.shed, serial.submitted
                ));
            }
            if serial.leftover_waiting != 0 {
                return Err(format!(
                    "{router}: {} requests stranded",
                    serial.leftover_waiting
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Explicit crash → recover: requeue-once, nothing lost, probes back
// ---------------------------------------------------------------------

#[test]
fn crash_then_recover_completes_everything() {
    let trace = trace_of(9, 2, 10, 25);
    // recover mid-backlog: probing back to Healthy needs routed work
    // (an idle replica has nothing to heartbeat about)
    let plan = FaultPlan::parse("crash@6:r0,recover@20:r0").unwrap();
    for router in ALL_ROUTERS {
        let res = run_fleet_faulted(
            &cfg_of(3, 9, 1),
            router,
            &trace,
            &[],
            None,
            Some(&plan),
        )
        .unwrap();
        assert_eq!(res.crashes, 1, "{router}");
        assert_eq!(res.recoveries, 1, "{router}");
        // in-flight work at the crash was requeued, not dropped ...
        assert!(res.requeued >= 1, "{router}: nothing requeued");
        // ... and with two healthy survivors nothing had to shed
        assert_eq!(res.shed, 0, "{router}");
        assert_eq!(res.completed, res.submitted, "{router}");
        assert_eq!(res.leftover_waiting, 0, "{router}");
        // the recovered replica probed its way back to Healthy
        assert_eq!(res.per_replica[0].health, ReplicaHealth::Healthy, "{router}");
    }
}

// ---------------------------------------------------------------------
// Fail-slow: detected as Suspect, work shifts off the slow replica
// ---------------------------------------------------------------------

#[test]
fn stall_marks_suspect_and_sheds_load_off_the_slow_replica() {
    let trace = trace_of(4, 2, 8, 40);
    let plan = FaultPlan::parse("stall@5:r0x4").unwrap();
    let cfg = cfg_of(3, 4, 1);
    let clean = run_fleet(&cfg, "low", &trace, &[]).unwrap();
    let res =
        run_fleet_faulted(&cfg, "low", &trace, &[], None, Some(&plan)).unwrap();
    assert_eq!(res.stalls, 1);
    assert_eq!(res.crashes, 0);
    // hidden 4x slowdown vs declared speed -> EWMA trips the monitor
    assert_eq!(res.per_replica[0].health, ReplicaHealth::Suspect);
    // a stall loses no work, it only slows it
    assert_eq!(res.completed, res.submitted);
    assert_eq!(res.shed, 0);
    // the router routed less onto the stalled replica than it did in
    // the clean run (queue pressure + Suspect penalty)
    assert!(
        res.per_replica[0].routed < clean.per_replica[0].routed,
        "stalled replica kept its load: {} vs clean {}",
        res.per_replica[0].routed,
        clean.per_replica[0].routed
    );
}

// ---------------------------------------------------------------------
// Regression: drain re-routing while another replica is Down
// ---------------------------------------------------------------------

#[test]
fn drain_reroute_skips_a_down_replica() {
    let trace = trace_of(13, 2, 10, 30);
    // r2 crashes (Down after the miss window) and never recovers; r0
    // drains at round 12, so its queue re-offers while r2 is Down.
    // Mis-routing any of it to r2 would strand work and break the
    // conservation accounting below.
    let plan = FaultPlan::parse("crash@5:r2").unwrap();
    let events = [FleetEvent::Drain { round: 12, replica: 0 }];
    for router in ALL_ROUTERS {
        let res = run_fleet_faulted(
            &cfg_of(3, 13, 1),
            router,
            &trace,
            &events,
            None,
            Some(&plan),
        )
        .unwrap();
        assert_eq!(res.per_replica[2].health, ReplicaHealth::Down, "{router}");
        assert_eq!(res.per_replica[2].leftover_waiting, 0, "{router}");
        assert_eq!(
            res.completed + res.shed,
            res.submitted,
            "{router}: work lost"
        );
        assert_eq!(res.leftover_waiting, 0, "{router}");
    }
}

// ---------------------------------------------------------------------
// Gateway degradation: bounded retries, then a well-formed 503
// ---------------------------------------------------------------------

/// A backend with no capacity: every completion fails, as when the
/// whole fleet is Down and the scheduler sheds.
struct ShedBackend;

impl Backend for ShedBackend {
    fn name(&self) -> String {
        "shed".to_string()
    }

    fn complete(&self, req: CompletionRequest) -> anyhow::Result<Completion> {
        bail!("request {} shed: no accepting replica", req.id)
    }

    fn workers(&self) -> Vec<WorkerStatus> {
        Vec::new()
    }

    fn stats(&self) -> BackendStats {
        BackendStats::default()
    }
}

#[test]
fn gateway_sheds_with_retry_after_and_counters() {
    let gw = Gateway::spawn(
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 2,
            ..GatewayConfig::default()
        },
        Arc::new(ShedBackend),
    )
    .unwrap();
    let a = gw.addr.to_string();

    let body = r#"{"prompt": [1, 2, 3], "max_tokens": 4}"#;
    let r = ghttp::http_call(&a, "POST", "/v1/completions", Some(body)).unwrap();
    assert_eq!(r.status, 503, "body: {}", r.body_str().unwrap_or(""));
    assert_eq!(r.header("Retry-After"), Some("1"), "missing Retry-After");
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    let msg = v.get("error").unwrap().as_str().unwrap();
    assert!(msg.contains("retries"), "error body: {msg}");

    // one shed request = MAX_RETRIES retries + one shed, both exported
    let m = ghttp::http_call(&a, "GET", "/metrics", None).unwrap();
    assert_eq!(m.status, 200);
    let text = m.body_str().unwrap();
    let metric = |name: &str| -> f64 {
        text.lines()
            .find(|l| l.starts_with(name) && !l.starts_with('#'))
            .and_then(|l| l.split_whitespace().last())
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("metric {name} missing:\n{text}"))
    };
    assert_eq!(metric("bfio_gateway_retries_total") as u64, 2);
    assert_eq!(metric("bfio_gateway_shed_total") as u64, 1);
}
