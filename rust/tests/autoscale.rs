//! Autoscale control-plane invariants:
//!
//! * Theorem 4's sandwich `0 ≤ correction ≤ κ·D_γ·ImbTot` holds per
//!   replica and fleet-wide under lifecycle churn (property suite);
//! * the controller with hysteresis never flaps on constant-rate load;
//! * an autoscaler-disabled (static-policy) fleet reproduces the PR-3
//!   open-loop `run_fleet` results to 1e-9;
//! * `/v0/admin/replicas` drains and re-adds a replica on a *live*
//!   `FleetBackend` under concurrent traffic without losing or
//!   duplicating a single request (end-to-end over HTTP).

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

use bfio_serve::autoscale::{run_autoscaled, AutoscaleConfig};
use bfio_serve::config::PowerConfig;
use bfio_serve::fleet::{
    run_fleet, FleetBackend, FleetBackendConfig, FleetConfig, FleetEvent,
};
use bfio_serve::gateway::http as ghttp;
use bfio_serve::gateway::{Gateway, GatewayConfig};
use bfio_serve::util::json::Json;
use bfio_serve::util::prop::Prop;
use bfio_serve::util::rng::Rng;
use bfio_serve::workload::{
    generate_trace, ArrivalProcess, GeometricSampler, HomogeneousSampler,
    Request,
};

fn geometric_trace(seed: u64, per_step: usize, backlog: usize, steps: u64) -> Vec<Request> {
    let mut sampler = GeometricSampler::new(5, 80, 0.25);
    sampler.o_cap = 12;
    let arrivals = ArrivalProcess::Fixed { per_step, initial_backlog: backlog };
    let mut rng = Rng::new(seed);
    generate_trace(&sampler, &arrivals, steps, &mut rng)
}

// ---------------------------------------------------------------------
// (a) Theorem 4 sandwich per replica and fleet-wide, under churn
// ---------------------------------------------------------------------

#[test]
fn prop_theorem4_sandwich_holds_per_replica_under_churn() {
    let power = PowerConfig::a100();
    let d_gamma = power.d_gamma();
    Prop::new(20).check(
        "theorem4-sandwich",
        |r| {
            let replicas = 2 + r.below_usize(3);
            let g = 1 + r.below_usize(3);
            let b = 1 + r.below_usize(3);
            let seed = r.next_u64();
            let churn = r.below(2) == 0;
            (replicas, g, b, seed, churn)
        },
        |&(replicas, g, b, seed, churn)| {
            let trace = geometric_trace(seed, 2, 10, 25);
            let cfg = FleetConfig {
                seed,
                ..FleetConfig::uniform(replicas, g, b, "jsq")
            };
            let events = if churn {
                vec![
                    FleetEvent::Drain { round: 8, replica: 0 },
                    FleetEvent::Add { round: 12, speed: 1.5 },
                    FleetEvent::Remove { round: 16, replica: 1 },
                ]
            } else {
                Vec::new()
            };
            let res = run_fleet(&cfg, "low", &trace, &events)
                .map_err(|e| e.to_string())?;
            let mut fleet_corr = 0.0;
            let mut fleet_bound = 0.0;
            for rep in &res.per_replica {
                let r = &rep.report;
                let kappa = cfg.t_token / rep.speed;
                let bound = kappa * d_gamma * r.imb_tot;
                if r.energy_correction_j < -1e-12 {
                    return Err(format!(
                        "replica {}: negative correction {}",
                        rep.id, r.energy_correction_j
                    ));
                }
                if r.energy_correction_j > bound + 1e-9 * bound.max(1.0) {
                    return Err(format!(
                        "replica {}: correction {} above k*D*ImbTot {}",
                        rep.id, r.energy_correction_j, bound
                    ));
                }
                // exactness: useful + idle + correction == sync energy
                let total = r.energy_useful_j
                    + r.energy_idle_j
                    + r.energy_correction_j;
                if (total - r.sync_energy_j).abs()
                    > 1e-9 * r.sync_energy_j.max(1.0)
                {
                    return Err(format!(
                        "replica {}: decomposition {} != sync {}",
                        rep.id, total, r.sync_energy_j
                    ));
                }
                fleet_corr += r.energy_correction_j;
                fleet_bound += bound;
            }
            if fleet_corr < -1e-12
                || fleet_corr > fleet_bound + 1e-9 * fleet_bound.max(1.0)
            {
                return Err(format!(
                    "fleet-wide sandwich violated: {fleet_corr} vs {fleet_bound}"
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// (b) hysteresis: no flapping on constant-rate load
// ---------------------------------------------------------------------

/// Deterministic constant load (fixed arrivals, fixed decode length):
/// after the admission ramp the active set is exactly constant, so a
/// correctly damped controller must settle and never act again.  The
/// initial backlog keeps even the ramp inside the hold band.
#[test]
fn controller_never_flaps_on_constant_load() {
    // 2/round at o=8 over 3x(2x4)=24 slots: the in-system count stays
    // in [14, 20] after the ramp — strictly inside the down gate
    // (<= 11.2 for `energy`, <= 8.4 for `target`) and the up gate
    // (>= 22.08) — so a damped controller must hold throughout.
    let sampler = HomogeneousSampler { s_min: 10, s_max: 20, o: 8 };
    let arrivals = ArrivalProcess::Fixed { per_step: 2, initial_backlog: 12 };
    let mut rng = Rng::new(11);
    let trace = generate_trace(&sampler, &arrivals, 400, &mut rng);
    for policy in ["target", "energy"] {
        let cfg = FleetConfig {
            seed: 3,
            ..FleetConfig::uniform(3, 2, 4, "jsq")
        };
        let auto = AutoscaleConfig {
            policy: policy.to_string(),
            min_replicas: 1,
            max_replicas: 3,
            cooldown_rounds: 10,
            dwell_rounds: 3,
            add_speed: 1.0,
        };
        let res = run_autoscaled(&cfg, "low", &auto, &trace, &[]).unwrap();
        assert_eq!(
            res.fleet.completed as usize,
            trace.len(),
            "{policy}: completes"
        );
        assert!(
            res.actions.is_empty(),
            "{policy}: controller flapped on constant load: {:?}",
            res.actions
        );
        assert!(res.controller.ticks > 100, "{policy}: controller ran");
    }
}

/// The no-flap scenario re-run under parallel round execution
/// (`threads = 8`): the controller must still settle and never act, and
/// the whole closed-loop run must match the serial one — the parallel
/// executor feeds the controller the same signal every round.
#[test]
fn fleet_parity_no_flap_rerun_under_parallel_rounds() {
    let sampler = HomogeneousSampler { s_min: 10, s_max: 20, o: 8 };
    let arrivals = ArrivalProcess::Fixed { per_step: 2, initial_backlog: 12 };
    let mut rng = Rng::new(11);
    let trace = generate_trace(&sampler, &arrivals, 400, &mut rng);
    for policy in ["target", "energy"] {
        let auto = AutoscaleConfig {
            policy: policy.to_string(),
            min_replicas: 1,
            max_replicas: 3,
            cooldown_rounds: 10,
            dwell_rounds: 3,
            add_speed: 1.0,
        };
        let serial_cfg = FleetConfig {
            seed: 3,
            threads: 1,
            ..FleetConfig::uniform(3, 2, 4, "jsq")
        };
        let serial = run_autoscaled(&serial_cfg, "low", &auto, &trace, &[]).unwrap();
        let par_cfg = FleetConfig { threads: 8, ..serial_cfg.clone() };
        let par = run_autoscaled(&par_cfg, "low", &auto, &trace, &[]).unwrap();
        assert_eq!(par.fleet.completed as usize, trace.len(), "{policy}");
        assert!(
            par.actions.is_empty(),
            "{policy}: controller flapped under threads=8: {:?}",
            par.actions
        );
        assert_eq!(serial.fleet.completed, par.fleet.completed, "{policy}");
        assert_eq!(serial.fleet.rounds, par.fleet.rounds, "{policy}");
        assert_eq!(serial.fleet.steps, par.fleet.steps, "{policy}");
        assert_eq!(serial.controller.ticks, par.controller.ticks, "{policy}");
        assert!(
            (serial.fleet.makespan_s - par.fleet.makespan_s).abs()
                <= 1e-9 * serial.fleet.makespan_s.max(1.0),
            "{policy}: makespan {} vs {}",
            serial.fleet.makespan_s,
            par.fleet.makespan_s
        );
    }
}

// ---------------------------------------------------------------------
// (b2) zero-alloc signal path: steady-state ticks never snapshot
// ---------------------------------------------------------------------

/// The PR-2 zero-alloc steady state, restored: `Controller::tick`
/// samples the core's borrowed replica views, so a full closed-loop run
/// — ticks plus rounds, serial or parallel — performs **zero** calls to
/// the cold-path `FleetCore::snapshot` API (O(R·G) allocation per
/// call, which used to run twice per round).
#[test]
fn controller_ticks_take_zero_snapshots() {
    use bfio_serve::autoscale::Controller;
    use bfio_serve::fleet::FleetCore;
    for threads in [1usize, 2] {
        let cfg = FleetConfig {
            seed: 1,
            threads,
            ..FleetConfig::uniform(3, 2, 4, "jsq")
        };
        let router = cfg.router("low").unwrap();
        let mut core: FleetCore<u32, ()> =
            FleetCore::new(cfg.clone(), router).unwrap();
        let auto = AutoscaleConfig {
            policy: "energy".to_string(),
            cooldown_rounds: 5,
            dwell_rounds: 2,
            ..AutoscaleConfig::default()
        };
        let mut controller = Controller::new(&auto, &cfg).unwrap();
        let trace = geometric_trace(5, 2, 10, 40);
        let mut ptr = 0usize;
        let mut out = Vec::new();
        for round in 0..400u64 {
            while ptr < trace.len() && trace[ptr].arrival_step <= round {
                core.submit(trace[ptr].prefill, trace[ptr].arrival_step, ptr as u32);
                ptr += 1;
            }
            controller.tick(&mut core);
            core.run_round(
                &|_, idx| {
                    let r = &trace[idx as usize];
                    (r.id, r.decode_len, ())
                },
                &mut out,
            );
            if core.is_idle() && ptr >= trace.len() {
                break;
            }
        }
        assert!(controller.state().ticks > 0);
        assert_eq!(
            core.snapshots_taken(),
            0,
            "threads={threads}: a steady-state tick used the cold-path snapshot API"
        );
    }
}

// ---------------------------------------------------------------------
// (c) static policy ≡ open-loop run_fleet, to 1e-9
// ---------------------------------------------------------------------

#[test]
fn static_policy_reproduces_open_loop_run_fleet() {
    let trace = geometric_trace(21, 3, 20, 30);
    for router in ["wrr", "low", "powd:2", "bfio2"] {
        let cfg = FleetConfig {
            seed: 9,
            record_completions: true,
            ..FleetConfig::uniform(3, 2, 3, "least")
        };
        let open = run_fleet(&cfg, router, &trace, &[]).unwrap();
        let auto = AutoscaleConfig {
            policy: "static".to_string(),
            ..AutoscaleConfig::default()
        };
        let closed = run_autoscaled(&cfg, router, &auto, &trace, &[]).unwrap();
        assert!(closed.actions.is_empty());
        let c = &closed.fleet;
        assert_eq!(open.completed, c.completed, "{router}");
        assert_eq!(open.rounds, c.rounds, "{router}");
        assert_eq!(open.steps, c.steps, "{router}");
        let close = |a: f64, b: f64, what: &str| {
            let scale = 1.0_f64.max(a.abs()).max(b.abs());
            assert!(
                (a - b).abs() <= 1e-9 * scale,
                "{router}: {what}: open {a:.17e} vs closed {b:.17e}"
            );
        };
        close(open.makespan_s, c.makespan_s, "makespan");
        close(open.energy_j, c.energy_j, "energy");
        close(open.avg_imbalance, c.avg_imbalance, "imbalance");
        close(open.tpot_s, c.tpot_s, "tpot");
        let ra: Vec<u64> = open.per_replica.iter().map(|r| r.routed).collect();
        let rb: Vec<u64> = c.per_replica.iter().map(|r| r.routed).collect();
        assert_eq!(ra, rb, "{router}: per-replica routing identical");
    }
}

// ---------------------------------------------------------------------
// admin API end-to-end: drain + re-add on a live FleetBackend
// ---------------------------------------------------------------------

#[test]
fn admin_drain_and_readd_live_without_losing_requests() {
    let backend = FleetBackend::new(FleetBackendConfig {
        replicas: 2,
        g: 2,
        b: 2,
        policy: "jsq".to_string(),
        router: "low".to_string(),
        step_delay: Duration::from_millis(1),
        batch_window: Duration::from_millis(5),
        ..FleetBackendConfig::default()
    })
    .unwrap();
    let gw = Gateway::spawn(
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 16,
            ..GatewayConfig::default()
        },
        Arc::new(backend),
    )
    .unwrap();
    let a = gw.addr.to_string();

    // Concurrent completions racing the lifecycle commands below.
    let n = 24usize;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let a = a.clone();
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt": [3, 4, {i}], "max_tokens": 6}}"#
                );
                let r = ghttp::http_call(&a, "POST", "/v1/completions", Some(&body))
                    .unwrap();
                assert_eq!(r.status, 200, "body: {}", r.body_str().unwrap_or(""));
                let v = Json::parse(r.body_str().unwrap()).unwrap();
                v.get("bfio")
                    .unwrap()
                    .get("request_id")
                    .unwrap()
                    .as_u64()
                    .unwrap()
            })
        })
        .collect();

    // Drain replica 0 mid-flight, then warm re-add it.
    let r = ghttp::http_call(
        &a,
        "POST",
        "/v0/admin/replicas",
        Some(r#"{"action": "drain", "replica": 0}"#),
    )
    .unwrap();
    assert_eq!(r.status, 200, "drain: {}", r.body_str().unwrap_or(""));
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    assert_eq!(v.get("ok").unwrap().as_bool().unwrap(), true);

    std::thread::sleep(Duration::from_millis(30));
    let r = ghttp::http_call(
        &a,
        "POST",
        "/v0/admin/replicas",
        Some(r#"{"action": "reactivate", "replica": 0}"#),
    )
    .unwrap();
    assert_eq!(r.status, 200, "reactivate: {}", r.body_str().unwrap_or(""));

    // Every request completes exactly once.
    let mut ids: Vec<u64> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    ids.sort_unstable();
    let uniq: HashSet<u64> = ids.iter().copied().collect();
    assert_eq!(uniq.len(), n, "no duplicated responses");
    assert_eq!(ids, (0..n as u64).collect::<Vec<u64>>(), "no lost requests");

    // Admin GET reflects the final lifecycle state.
    let r = ghttp::http_call(&a, "GET", "/v0/admin/replicas", None).unwrap();
    assert_eq!(r.status, 200);
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    let reps = v.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(reps.len(), 2);
    assert!(reps
        .iter()
        .all(|r| r.get("state").unwrap().as_str().unwrap() == "accepting"));
    assert!(v.get("autoscaler").unwrap() == &Json::Null);
    let done: u64 = reps
        .iter()
        .map(|r| r.get("completed").unwrap().as_u64().unwrap())
        .sum();
    assert_eq!(done, n as u64, "completions accounted once across replicas");

    // A cold add appears in the admin view and serves traffic.
    let r = ghttp::http_call(
        &a,
        "POST",
        "/v0/admin/replicas",
        Some(r#"{"action": "add", "speed": 2.0}"#),
    )
    .unwrap();
    assert_eq!(r.status, 200);
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    assert_eq!(v.get("replica").unwrap().as_usize().unwrap(), 2);
    let r = ghttp::http_call(&a, "GET", "/v0/admin/replicas", None).unwrap();
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    assert_eq!(v.get("replicas").unwrap().as_arr().unwrap().len(), 3);

    // Unknown action and unknown replica are 400s, not 500s.
    let r = ghttp::http_call(
        &a,
        "POST",
        "/v0/admin/replicas",
        Some(r#"{"action": "explode"}"#),
    )
    .unwrap();
    assert_eq!(r.status, 400);
    let r = ghttp::http_call(
        &a,
        "POST",
        "/v0/admin/replicas",
        Some(r#"{"action": "drain", "replica": 99}"#),
    )
    .unwrap();
    assert_eq!(r.status, 400);
    gw.shutdown();
}

// ---------------------------------------------------------------------
// autoscaled gateway: controller state over HTTP + metrics families
// ---------------------------------------------------------------------

#[test]
fn autoscaled_gateway_exposes_controller_state_and_metrics() {
    let backend = FleetBackend::new(FleetBackendConfig {
        replicas: 2,
        g: 2,
        b: 2,
        policy: "jsq".to_string(),
        router: "low".to_string(),
        step_delay: Duration::ZERO,
        batch_window: Duration::ZERO,
        autoscale: Some(AutoscaleConfig {
            policy: "energy".to_string(),
            min_replicas: 1,
            max_replicas: 2,
            cooldown_rounds: 4,
            dwell_rounds: 2,
            add_speed: 1.0,
        }),
        ..FleetBackendConfig::default()
    })
    .unwrap();
    let gw = Gateway::spawn(
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 8,
            ..GatewayConfig::default()
        },
        Arc::new(backend),
    )
    .unwrap();
    let a = gw.addr.to_string();

    for i in 0..8 {
        let body = format!(r#"{{"prompt": [1, {i}], "max_tokens": 3}}"#);
        let r = ghttp::http_call(&a, "POST", "/v1/completions", Some(&body))
            .unwrap();
        assert_eq!(r.status, 200);
    }

    let r = ghttp::http_call(&a, "GET", "/v0/admin/replicas", None).unwrap();
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    let auto = v.get("autoscaler").unwrap();
    assert!(auto.get("policy").unwrap().as_str().unwrap().starts_with("energy"));
    assert_eq!(auto.get("paused").unwrap().as_bool().unwrap(), false);
    assert!(auto.get("ticks").unwrap().as_u64().unwrap() > 0);

    let r = ghttp::http_call(&a, "GET", "/metrics", None).unwrap();
    let text = r.body_str().unwrap();
    assert!(text.contains("# TYPE bfio_autoscale_replicas gauge"));
    assert!(text.contains("bfio_autoscale_replicas{state=\"accepting\"}"));
    assert!(text.contains("bfio_autoscale_actions_total{action=\"drain\"}"));
    assert!(text.contains("bfio_autoscale_ticks_total"));
    assert!(text.contains("bfio_energy_useful_joules"));
    assert!(text.contains("bfio_energy_idle_joules"));
    assert!(text.contains("bfio_replica_energy_useful_joules{replica=\"0\"}"));

    // Pause over HTTP, visible in both views.
    let r = ghttp::http_call(
        &a,
        "POST",
        "/v0/admin/replicas",
        Some(r#"{"action": "pause"}"#),
    )
    .unwrap();
    assert_eq!(r.status, 200);
    let r = ghttp::http_call(&a, "GET", "/v0/admin/replicas", None).unwrap();
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    assert_eq!(
        v.get("autoscaler")
            .unwrap()
            .get("paused")
            .unwrap()
            .as_bool()
            .unwrap(),
        true
    );
    let r = ghttp::http_call(&a, "GET", "/metrics", None).unwrap();
    assert!(r.body_str().unwrap().contains("bfio_autoscale_paused 1"));
    gw.shutdown();
}
