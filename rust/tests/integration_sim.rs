//! Cross-module integration tests: simulator × policies × energy over
//! realistic workloads, checking the paper's structural claims end to end.

use bfio_serve::config::SimConfig;
use bfio_serve::policies::bfio::BfIo;
use bfio_serve::policies::by_name;
use bfio_serve::sim::predictor::Predictor;
use bfio_serve::sim::Simulator;
use bfio_serve::util::rng::Rng;
use bfio_serve::workload::adversarial::overloaded_trace;
use bfio_serve::workload::longbench::LongBenchLike;
use bfio_serve::workload::{Drift, GeometricSampler};

fn cfg(g: usize, b: usize, steps: u64) -> SimConfig {
    SimConfig {
        g,
        b,
        max_steps: steps,
        warmup_steps: steps / 5,
        seed: 11,
        ..SimConfig::default()
    }
}

fn lb_trace(g: usize, b: usize, steps: u64, seed: u64) -> Vec<bfio_serve::workload::Request> {
    let sampler = LongBenchLike::paper();
    let mut rng = Rng::new(seed);
    overloaded_trace(&sampler, g, b, steps, 3.0, &mut rng)
}

#[test]
fn all_policies_run_and_conserve_workload() {
    // Eq. 11: W(I) is policy-independent over the processed window when
    // the instance fully drains.
    let sampler = GeometricSampler::new(5, 200, 0.2);
    let mut rng = Rng::new(3);
    let trace = overloaded_trace(&sampler, 4, 8, 60, 2.0, &mut rng);
    let expect: f64 = trace.iter().map(|r| r.total_workload(&Drift::Unit)).sum();
    let c = SimConfig { g: 4, b: 8, max_steps: 0, seed: 3, ..SimConfig::default() };
    let sim = Simulator::new(c);
    for name in [
        "fcfs", "jsq", "rr", "pow2", "powd:3", "least", "minmin", "maxmin",
        "throttled:0.9", "bfio:0", "bfio:20",
    ] {
        let mut p = by_name(name).unwrap();
        let res = sim.run(&trace, p.as_mut());
        assert_eq!(res.completed as usize, trace.len(), "{name} must drain");
        assert!(
            (res.report.total_workload - expect).abs() < 1e-6 * expect,
            "{name}: W(I) {} vs {}",
            res.report.total_workload,
            expect
        );
    }
}

#[test]
fn paper_ordering_on_longbench_like_load() {
    // The Table-1 ordering at a moderate scale: BF-IO(40) < BF-IO(0) <
    // FCFS on imbalance; throughput reversed; energy reversed.
    let trace = lb_trace(16, 16, 400, 5);
    let sim = Simulator::new(cfg(16, 16, 400));
    let fcfs = sim.run(&trace, &mut *by_name("fcfs").unwrap());
    let bf0 = sim.run(&trace, &mut BfIo::with_horizon(0));
    let bf40 = sim.run(&trace, &mut BfIo::with_horizon(40));

    assert!(bf0.report.avg_imbalance < fcfs.report.avg_imbalance);
    // With an oracle predictor and instantaneous refill, H=0 is already
    // near-optimal; H=40 must stay in the same band (EXPERIMENTS.md
    // §Fig 9 discusses this deviation from the paper's H-curve).
    assert!(bf40.report.avg_imbalance < 1.5 * bf0.report.avg_imbalance);
    // √(B log G) is modest at G=B=16; the gap widens with scale
    // (see the --full runs in EXPERIMENTS.md).
    assert!(bf40.report.avg_imbalance < 0.75 * fcfs.report.avg_imbalance);
    assert!(bf40.report.throughput_tps > fcfs.report.throughput_tps);
    assert!(bf40.report.total_energy_j < fcfs.report.total_energy_j);
    assert!(bf40.report.tpot_s < fcfs.report.tpot_s);
    assert!(bf40.report.mean_idle_fraction < fcfs.report.mean_idle_fraction);
}

#[test]
fn iir_grows_with_batch_size() {
    // Theorem 2's √B dependence, coarsely: doubling B must not shrink
    // the FCFS/BF-IO imbalance ratio.
    let sampler = GeometricSampler::new(1, 300, 0.1);
    let measure = |b: usize| {
        let mut rng = Rng::new(17);
        let trace = overloaded_trace(&sampler, 8, b, 300, 3.0, &mut rng);
        let sim = Simulator::new(cfg(8, b, 300));
        let f = sim.run(&trace, &mut *by_name("fcfs").unwrap());
        let bf = sim.run(&trace, &mut BfIo::with_horizon(0));
        f.report.avg_imbalance / bf.report.avg_imbalance
    };
    let small = measure(8);
    let large = measure(32);
    assert!(large > small, "IIR must grow with B: {small} -> {large}");
    assert!(small > 1.0);
}

#[test]
fn lookahead_stays_in_band_with_oracle() {
    // Under an oracle predictor with mean-field refill, every horizon
    // must land in the same performance band as H=0 and far below FCFS:
    // the lookahead is never allowed to *hurt* (robustness claim; the
    // paper's H=40-optimum is discussed in EXPERIMENTS.md §Fig 9).
    let mut sums = [0.0f64; 3]; // fcfs, h0, h40
    for seed in [9u64, 10, 11] {
        let trace = lb_trace(32, 24, 400, seed);
        let mut c = cfg(32, 24, 400);
        c.seed = seed;
        let sim = Simulator::new(c).with_predictor(Predictor::Oracle);
        sums[0] += sim
            .run(&trace, &mut *by_name("fcfs").unwrap())
            .report
            .avg_imbalance;
        sums[1] += sim.run(&trace, &mut BfIo::with_horizon(0)).report.avg_imbalance;
        sums[2] += sim.run(&trace, &mut BfIo::with_horizon(40)).report.avg_imbalance;
    }
    assert!(sums[1] < 0.6 * sums[0], "h0 {} vs fcfs {}", sums[1], sums[0]);
    assert!(sums[2] < 0.6 * sums[0], "h40 {} vs fcfs {}", sums[2], sums[0]);
    assert!(
        sums[2] < 1.4 * sums[1],
        "h40 {} must stay in h0's band {}",
        sums[2],
        sums[1]
    );
}

#[test]
fn pessimistic_predictor_degrades_to_myopic_not_worse() {
    // With no lookahead signal at all, BF-IO(H=40) must still be at
    // least as good as FCFS (graceful degradation claim).
    let trace = lb_trace(8, 16, 300, 13);
    let sim = Simulator::new(cfg(8, 16, 300)).with_predictor(Predictor::Pessimistic);
    let fcfs = sim.run(&trace, &mut *by_name("fcfs").unwrap());
    let bf = sim.run(&trace, &mut BfIo::with_horizon(40));
    assert!(bf.report.avg_imbalance < fcfs.report.avg_imbalance);
}

#[test]
fn energy_sandwich_holds_on_full_runs() {
    // Theorem 4's proof inequality on a complete run:
    // κ·P_max·W + κ·P_idle·ImbTot <= E_sync <= κ·P_max·W + κ·C_γ·ImbTot.
    let sampler = GeometricSampler::new(5, 200, 0.2);
    let mut rng = Rng::new(19);
    let trace = overloaded_trace(&sampler, 4, 8, 80, 2.0, &mut rng);
    let c = SimConfig { g: 4, b: 8, max_steps: 0, seed: 19, ..SimConfig::default() };
    let power = bfio_serve::config::PowerConfig::a100();
    let sim = Simulator::new(c.clone());
    for name in ["fcfs", "bfio:0"] {
        let res = sim.run(&trace, &mut *by_name(name).unwrap());
        let kappa = c.t_token;
        let lo = kappa * (power.p_max * res.report.total_workload
            + power.p_idle * res.report.imb_tot);
        let hi = kappa * (power.p_max * res.report.total_workload
            + power.c_gamma() * res.report.imb_tot);
        let e = res.report.sync_energy_j;
        assert!(e >= lo - 1e-6 * e, "{name}: E {e} < lower {lo}");
        assert!(e <= hi + 1e-6 * e, "{name}: E {e} > upper {hi}");
    }
}

#[test]
fn drift_models_all_preserve_bfio_advantage() {
    // Theorem 3's generality: the improvement holds for every drift in
    // the non-decreasing family.
    for drift in [
        Drift::Unit,
        Drift::Zero,
        Drift::Const(0.5),
        Drift::Speculative(2.0),
        Drift::Cycle(vec![1.0, 0.0]),
    ] {
        let sampler = GeometricSampler::new(1, 300, 0.1);
        let mut rng = Rng::new(23);
        let trace = overloaded_trace(&sampler, 8, 16, 250, 3.0, &mut rng);
        let mut c = cfg(8, 16, 250);
        c.drift = drift.clone();
        let sim = Simulator::new(c);
        let f = sim.run(&trace, &mut *by_name("fcfs").unwrap());
        let b = sim.run(&trace, &mut BfIo::with_horizon(0));
        assert!(
            b.report.avg_imbalance < f.report.avg_imbalance,
            "drift {:?}: bfio {} vs fcfs {}",
            drift,
            b.report.avg_imbalance,
            f.report.avg_imbalance
        );
    }
}

#[test]
fn tpot_improves_under_bfio() {
    let trace = lb_trace(16, 16, 500, 29);
    let sim = Simulator::new(cfg(16, 16, 500));
    let f = sim.run(&trace, &mut *by_name("fcfs").unwrap());
    let b = sim.run(&trace, &mut BfIo::with_horizon(40));
    assert!(b.report.tpot_s <= f.report.tpot_s * 1.02);
}

#[test]
fn throttled_not_work_conserving_hurts_throughput() {
    // The paper's point about TLB: capping concurrency leaves slots idle.
    let trace = lb_trace(8, 16, 300, 31);
    let sim = Simulator::new(cfg(8, 16, 300));
    let full = sim.run(&trace, &mut *by_name("fcfs").unwrap());
    let throttled = sim.run(&trace, &mut *by_name("throttled:0.5").unwrap());
    assert!(throttled.report.total_tokens < full.report.total_tokens * 0.8);
}
