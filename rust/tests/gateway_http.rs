//! End-to-end gateway integration: boots the HTTP server on a loopback
//! port with the discrete-event [`SimBackend`] (no GPUs), issues
//! completions over raw `TcpStream`s, and checks routing statistics and
//! the Prometheus `/metrics` exposition.

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

use bfio_serve::gateway::http as ghttp;
use bfio_serve::gateway::loadgen::{self, LoadGenConfig};
use bfio_serve::gateway::sim::{SimBackend, SimBackendConfig};
use bfio_serve::gateway::{Gateway, GatewayConfig};
use bfio_serve::metrics::prometheus;
use bfio_serve::util::json::Json;

/// Boot a gateway on an ephemeral loopback port.
fn boot(policy: &str, step_delay_ms: u64, batch_window_ms: u64) -> (Gateway, String) {
    let backend = SimBackend::new(SimBackendConfig {
        g: 4,
        b: 2,
        policy: policy.to_string(),
        step_delay: Duration::from_millis(step_delay_ms),
        batch_window: Duration::from_millis(batch_window_ms),
        ..SimBackendConfig::default()
    })
    .unwrap();
    let gw = Gateway::spawn(
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 16,
            ..GatewayConfig::default()
        },
        Arc::new(backend),
    )
    .unwrap();
    let authority = gw.addr.to_string();
    (gw, authority)
}

#[test]
fn healthz_root_and_404() {
    let (gw, a) = boot("fcfs", 0, 0);
    let r = ghttp::http_call(&a, "GET", "/healthz", None).unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(r.body_str().unwrap(), "ok\n");

    let r = ghttp::http_call(&a, "GET", "/", None).unwrap();
    assert_eq!(r.status, 200);
    assert!(r.body_str().unwrap().contains("/v1/completions"));

    let r = ghttp::http_call(&a, "GET", "/no/such/path", None).unwrap();
    assert_eq!(r.status, 404);

    let r = ghttp::http_call(&a, "GET", "/v1/completions", None).unwrap();
    assert_eq!(r.status, 405);
    gw.shutdown();
}

#[test]
fn completion_roundtrip_with_string_prompt() {
    let (gw, a) = boot("fcfs", 0, 0);
    let r = ghttp::http_call(
        &a,
        "POST",
        "/v1/completions",
        Some(r#"{"prompt": "hello brave new world", "max_tokens": 5}"#),
    )
    .unwrap();
    assert_eq!(r.status, 200, "body: {}", r.body_str().unwrap_or(""));
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    assert_eq!(v.get("object").unwrap().as_str().unwrap(), "text_completion");
    assert!(v.get("model").unwrap().as_str().unwrap().starts_with("sim/"));
    let usage = v.get("usage").unwrap();
    assert_eq!(usage.get("prompt_tokens").unwrap().as_u64().unwrap(), 4);
    assert_eq!(usage.get("completion_tokens").unwrap().as_u64().unwrap(), 5);
    assert_eq!(usage.get("total_tokens").unwrap().as_u64().unwrap(), 9);
    let text = v
        .get("choices")
        .unwrap()
        .idx(0)
        .unwrap()
        .get("text")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    assert_eq!(text.split_whitespace().count(), 5, "5 generated tokens");
    let b = v.get("bfio").unwrap();
    assert!(b.get("worker").unwrap().as_usize().unwrap() < 4);
    assert!(b.get("tpot_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(b.get("request_id").is_some());
    gw.shutdown();
}

#[test]
fn rejects_malformed_bodies() {
    let (gw, a) = boot("fcfs", 0, 0);
    for bad in [
        "not json at all",
        "[1, 2, 3]",
        "{}",
        r#"{"prompt": ""}"#,
        r#"{"prompt": []}"#,
    ] {
        let r = ghttp::http_call(&a, "POST", "/v1/completions", Some(bad)).unwrap();
        assert_eq!(r.status, 400, "body {bad:?} should be rejected");
    }
    // and the gateway still serves afterwards
    let r = ghttp::http_call(
        &a,
        "POST",
        "/v1/completions",
        Some(r#"{"prompt": [5, 6], "max_tokens": 2}"#),
    )
    .unwrap();
    assert_eq!(r.status, 200);
    gw.shutdown();
}

#[test]
fn concurrent_completions_route_across_workers() {
    // 12 closed-loop clients against G=4×B=2 slots: the dynamic-batching
    // window gathers the burst, so any load-aware policy must use >= 2
    // workers, and every request id must be unique.
    let (gw, a) = boot("jsq", 2, 40);
    let n = 12usize;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let a = a.clone();
            std::thread::spawn(move || {
                let body =
                    format!(r#"{{"prompt": [1, 2, 3, {i}], "max_tokens": 8}}"#);
                let r =
                    ghttp::http_call(&a, "POST", "/v1/completions", Some(&body))
                        .unwrap();
                assert_eq!(r.status, 200);
                let v = Json::parse(r.body_str().unwrap()).unwrap();
                let b = v.get("bfio").unwrap();
                (
                    b.get("request_id").unwrap().as_u64().unwrap(),
                    b.get("worker").unwrap().as_usize().unwrap(),
                )
            })
        })
        .collect();
    let results: Vec<(u64, usize)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    let ids: HashSet<u64> = results.iter().map(|r| r.0).collect();
    assert_eq!(ids.len(), n, "request ids must be unique: {results:?}");
    let used: HashSet<usize> = results.iter().map(|r| r.1).collect();
    assert!(
        used.len() >= 2,
        "12 concurrent requests all landed on one worker: {results:?}"
    );

    // /v0/workers accounting adds up.
    let r = ghttp::http_call(&a, "GET", "/v0/workers", None).unwrap();
    assert_eq!(r.status, 200);
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    assert_eq!(v.get("policy").unwrap().as_str().unwrap(), "JSQ");
    let per: u64 = v
        .get("workers")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|w| w.get("completed").unwrap().as_u64().unwrap())
        .sum();
    assert_eq!(per, n as u64);
    assert_eq!(v.get("workers").unwrap().as_arr().unwrap().len(), 4);
    gw.shutdown();
}

#[test]
fn admin_replicas_unsupported_on_sim_backend() {
    // The admin surface exists on every gateway, but a single-group
    // backend has no replica lifecycle: GET shows no autoscaler and
    // POST answers 501, not 500.
    let (gw, a) = boot("fcfs", 0, 0);
    let r = ghttp::http_call(&a, "GET", "/v0/admin/replicas", None).unwrap();
    assert_eq!(r.status, 200);
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    assert_eq!(v.get("autoscaler"), Some(&Json::Null));
    assert!(v.get("replicas").unwrap().as_arr().unwrap().is_empty());

    let r = ghttp::http_call(
        &a,
        "POST",
        "/v0/admin/replicas",
        Some(r#"{"action": "drain", "replica": 0}"#),
    )
    .unwrap();
    assert_eq!(r.status, 501, "body: {}", r.body_str().unwrap_or(""));
    // malformed admin bodies are still client errors
    let r = ghttp::http_call(&a, "POST", "/v0/admin/replicas", Some("[]")).unwrap();
    assert_eq!(r.status, 400);
    gw.shutdown();
}

#[test]
fn metrics_exposition_tracks_requests() {
    let (gw, a) = boot("bfio:8", 0, 0);
    for i in 0..3 {
        let body = format!(r#"{{"prompt": [9, 9, {i}], "max_tokens": 4}}"#);
        let r = ghttp::http_call(&a, "POST", "/v1/completions", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
    }
    let r = ghttp::http_call(&a, "GET", "/metrics", None).unwrap();
    assert_eq!(r.status, 200);
    let text = r.body_str().unwrap();
    assert!(text.contains("# TYPE bfio_worker_load gauge"));
    assert!(text.contains("# TYPE bfio_requests_total counter"));
    assert!(text.contains("bfio_requests_total{policy=\"BF-IO(H=8)\"}"));
    assert!(text.contains("bfio_energy_joules"));
    assert!(text.contains("bfio_imbalance"));
    assert_eq!(loadgen::prom_value(text, "bfio_requests_total"), Some(3.0));
    assert_eq!(loadgen::prom_value(text, "bfio_tokens_total"), Some(12.0));
    assert!(loadgen::prom_value(text, "bfio_energy_joules").unwrap() > 0.0);
    assert!(loadgen::prom_value(text, "bfio_http_requests_total").unwrap() >= 3.0);
    gw.shutdown();
}

#[test]
fn loadgen_end_to_end_reports_policy_table() {
    let (gw, a) = boot("bfio:8", 1, 10);
    let cfg = LoadGenConfig {
        authority: a.clone(),
        concurrency: 4,
        requests: 16,
        prompt_tokens: 8,
        max_tokens: 6,
        seed: 7,
        trace: None,
        ..LoadGenConfig::default()
    };
    let res = loadgen::run(&cfg).unwrap();
    assert_eq!(res.completed, 16);
    assert_eq!(res.errors, 0);
    assert!(res.tokens >= 16, "every request generates >= 1 token");
    let per: u64 = res.per_worker.values().sum();
    assert_eq!(per, 16);

    let (policy, report) = loadgen::fetch_report(&a, &res).unwrap();
    assert_eq!(policy, "BF-IO(H=8)");
    assert_eq!(report.completed, 16);
    assert!(report.steps > 0, "server-side steps via /metrics");
    assert!(report.total_energy_j > 0.0, "server-side energy via /metrics");
    assert!(report.avg_imbalance >= 0.0);
    assert!(report.throughput_tps > 0.0);
    assert!(report.tpot_s > 0.0);
    // the row renders without panicking
    let row = report.table_row(&policy);
    assert!(row.contains("BF-IO"));
    gw.shutdown();
}

#[test]
fn trace_endpoint_serves_complete_span_chains_and_metrics_lint_clean() {
    // Gateway with the flight recorder on: a completed request's whole
    // lifecycle is retrievable by id via /v0/trace, and the full live
    // /metrics exposition (histogram families included) lints clean.
    let backend = SimBackend::new(SimBackendConfig {
        g: 2,
        b: 2,
        policy: "fcfs".to_string(),
        step_delay: Duration::ZERO,
        batch_window: Duration::ZERO,
        trace: true,
        trace_buf: 512,
        ..SimBackendConfig::default()
    })
    .unwrap();
    let gw = Gateway::spawn(
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 8,
            ..GatewayConfig::default()
        },
        Arc::new(backend),
    )
    .unwrap();
    let a = gw.addr.to_string();

    let mut last_id = 0u64;
    for i in 0..4 {
        let body = format!(r#"{{"prompt": [7, 7, {i}], "max_tokens": 3}}"#);
        let r = ghttp::http_call(&a, "POST", "/v1/completions", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
        let v = Json::parse(r.body_str().unwrap()).unwrap();
        last_id = v
            .get("bfio")
            .unwrap()
            .get("request_id")
            .unwrap()
            .as_u64()
            .unwrap();
    }

    // Span chain for a known request id, as JSONL.
    let r = ghttp::http_call(
        &a,
        "GET",
        &format!("/v0/trace?last=256&id={last_id}"),
        None,
    )
    .unwrap();
    assert_eq!(r.status, 200);
    let body = r.body_str().unwrap().to_string();
    let mut lines = body.lines();
    // First JSONL line is the store header (drop counter), not a span.
    let header = Json::parse(lines.next().unwrap()).unwrap();
    assert_eq!(header.get("header").and_then(Json::as_bool), Some(true));
    assert!(header.get("dropped").unwrap().as_f64().unwrap() >= 0.0);
    let kinds: Vec<String> = lines
        .map(|l| {
            let ev = Json::parse(l).unwrap();
            assert_eq!(
                ev.get("request_id").unwrap().as_u64().unwrap(),
                last_id
            );
            ev.get("kind").unwrap().as_str().unwrap().to_string()
        })
        .collect();
    assert_eq!(
        kinds,
        vec!["arrival", "admit", "first_token", "finish"],
        "complete causal chain for request {last_id}"
    );

    // Chrome trace_event export of the same store.  The `metadata`
    // block carries the ring's drop counter so an eviction-truncated
    // export is distinguishable from a complete one.
    let r = ghttp::http_call(&a, "GET", "/v0/trace?format=chrome", None).unwrap();
    assert_eq!(r.status, 200);
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    assert!(!v.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    let dropped = v
        .get("metadata")
        .and_then(|m| m.get("dropped"))
        .and_then(Json::as_u64)
        .expect("chrome export carries metadata.dropped");
    assert_eq!(dropped, 0, "nothing should have been evicted in this run");

    // The live exposition: structurally clean, with the mergeable
    // latency histograms and the SLO-goodput gauge present.
    let r = ghttp::http_call(&a, "GET", "/metrics", None).unwrap();
    assert_eq!(r.status, 200);
    let text = r.body_str().unwrap();
    prometheus::lint(text).expect("live /metrics exposition must lint clean");
    assert!(text.contains("# TYPE bfio_ttft_seconds histogram"));
    assert!(text.contains("# TYPE bfio_tpot_seconds histogram"));
    assert!(text.contains("bfio_ttft_seconds_bucket"));
    assert!(text.contains("le=\"+Inf\""));
    let goodput = loadgen::prom_value(text, "bfio_slo_goodput_ratio").unwrap();
    assert!((0.0..=1.0).contains(&goodput));
    assert!(loadgen::prom_value(text, "bfio_ttft_seconds_count").unwrap() >= 4.0);
    gw.shutdown();
}

#[test]
fn journal_endpoint_is_404_without_a_journaling_backend() {
    let (gw, a) = boot("fcfs", 0, 0);
    let r = ghttp::http_call(&a, "GET", "/v0/journal", None).unwrap();
    assert_eq!(r.status, 404, "journaling is opt-in (fleet backend + --journal)");
    gw.shutdown();
}

#[test]
fn trace_endpoint_is_404_when_tracing_off() {
    let (gw, a) = boot("fcfs", 0, 0);
    let r = ghttp::http_call(&a, "GET", "/v0/trace", None).unwrap();
    assert_eq!(r.status, 404, "tracing is strictly opt-in");
    gw.shutdown();
}

#[test]
fn shutdown_is_idempotent_and_frees_the_port() {
    let (gw, a) = boot("fcfs", 0, 0);
    let r = ghttp::http_call(&a, "GET", "/healthz", None).unwrap();
    assert_eq!(r.status, 200);
    gw.shutdown();
    // The port no longer serves the gateway.
    assert!(ghttp::http_call(&a, "GET", "/healthz", None).is_err());
}

/// Parser hardening and connection-reuse semantics of the epoll
/// reactor (Linux-only: other platforms fall back to the thread pool,
/// which has its own cruder 400 path).
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod reactor_hardening {
    use super::*;
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;

    /// Gateway with tight parser limits so abuse tests run fast.
    fn boot_hardened() -> (Gateway, String) {
        let backend = SimBackend::new(SimBackendConfig {
            g: 2,
            b: 2,
            policy: "fcfs".to_string(),
            step_delay: Duration::ZERO,
            batch_window: Duration::ZERO,
            ..SimBackendConfig::default()
        })
        .unwrap();
        let gw = Gateway::spawn(
            GatewayConfig {
                addr: "127.0.0.1:0".to_string(),
                threads: 4,
                max_header_bytes: 1024,
                max_body_bytes: 2048,
                read_deadline: Duration::from_millis(300),
                ..GatewayConfig::default()
            },
            Arc::new(backend),
        )
        .unwrap();
        let a = gw.addr.to_string();
        (gw, a)
    }

    fn connect(a: &str) -> TcpStream {
        let s = TcpStream::connect(a).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s
    }

    /// Read one HTTP response (status + Content-Length-framed body).
    fn read_one(r: &mut BufReader<TcpStream>) -> (u16, String) {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .unwrap_or_else(|| panic!("bad status line {line:?}"))
            .parse()
            .unwrap();
        let mut clen = 0usize;
        loop {
            let mut h = String::new();
            r.read_line(&mut h).unwrap();
            if h == "\r\n" || h.is_empty() {
                break;
            }
            if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
                clen = v.trim().parse().unwrap();
            }
        }
        let mut body = vec![0u8; clen];
        r.read_exact(&mut body).unwrap();
        (status, String::from_utf8_lossy(&body).into_owned())
    }

    #[test]
    fn garbage_request_gets_400_then_close() {
        let (gw, a) = boot_hardened();
        let mut s = connect(&a);
        s.write_all(b"TOTAL NONSENSE\r\n\r\n").unwrap();
        let mut r = BufReader::new(s);
        let (status, _) = read_one(&mut r);
        assert_eq!(status, 400);
        // The framing is poisoned: the server closes the connection.
        let mut rest = Vec::new();
        r.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty());
        gw.shutdown();
    }

    #[test]
    fn truncated_request_times_out_with_408() {
        let (gw, a) = boot_hardened();
        let mut s = connect(&a);
        // Head never finishes: the read deadline (300ms) must answer
        // 408 and close instead of holding the slot open (slowloris).
        s.write_all(b"POST /v1/completions HTTP/1.1\r\nContent-Ty").unwrap();
        let mut r = BufReader::new(s);
        let (status, _) = read_one(&mut r);
        assert_eq!(status, 408);
        gw.shutdown();
    }

    #[test]
    fn oversized_head_gets_431() {
        let (gw, a) = boot_hardened();
        let mut s = connect(&a);
        let mut req = b"GET /healthz HTTP/1.1\r\nX-Pad: ".to_vec();
        req.extend(std::iter::repeat(b'a').take(4096));
        // No terminator yet — the limit must trip on buffered size.
        s.write_all(&req).unwrap();
        let mut r = BufReader::new(s);
        let (status, _) = read_one(&mut r);
        assert_eq!(status, 431);
        gw.shutdown();
    }

    #[test]
    fn oversized_declared_body_gets_413() {
        let (gw, a) = boot_hardened();
        let mut s = connect(&a);
        s.write_all(
            b"POST /v1/completions HTTP/1.1\r\nContent-Length: 1000000\r\n\r\n",
        )
        .unwrap();
        let mut r = BufReader::new(s);
        let (status, body) = read_one(&mut r);
        assert_eq!(status, 413, "body: {body}");
        gw.shutdown();
    }

    #[test]
    fn keep_alive_serves_sequential_requests_on_one_socket() {
        let (gw, a) = boot_hardened();
        let s = connect(&a);
        let mut r = BufReader::new(s);
        for _ in 0..3 {
            r.get_mut()
                .write_all(b"GET /healthz HTTP/1.1\r\n\r\n")
                .unwrap();
            let (status, body) = read_one(&mut r);
            assert_eq!(status, 200);
            assert_eq!(body, "ok\n");
        }
        gw.shutdown();
    }

    #[test]
    fn pipelined_requests_with_trailing_junk_answer_in_order() {
        let (gw, a) = boot_hardened();
        let mut s = connect(&a);
        s.write_all(
            b"GET /healthz HTTP/1.1\r\n\r\nGET / HTTP/1.1\r\n\r\nJUNK LINE\r\n\r\n",
        )
        .unwrap();
        let mut r = BufReader::new(s);
        let (s1, b1) = read_one(&mut r);
        let (s2, b2) = read_one(&mut r);
        let (s3, _) = read_one(&mut r);
        assert_eq!((s1, b1.as_str()), (200, "ok\n"));
        assert_eq!(s2, 200);
        assert!(b2.contains("/v1/completions"));
        // The junk's 400 comes *after* both good responses.
        assert_eq!(s3, 400);
        gw.shutdown();
    }

    #[test]
    fn pipelined_completions_answer_in_request_order() {
        let (gw, a) = boot_hardened();
        let q1 = r#"{"prompt": [1, 2], "max_tokens": 2}"#;
        let q2 = r#"{"prompt": [3, 4], "max_tokens": 3}"#;
        let mut req = Vec::new();
        for q in [q1, q2] {
            req.extend_from_slice(
                format!(
                    "POST /v1/completions HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
                    q.len(),
                    q
                )
                .as_bytes(),
            );
        }
        let mut s = connect(&a);
        s.write_all(&req).unwrap();
        let mut r = BufReader::new(s);
        let (s1, b1) = read_one(&mut r);
        let (s2, b2) = read_one(&mut r);
        assert_eq!((s1, s2), (200, 200));
        let n = |b: &str| {
            Json::parse(b)
                .unwrap()
                .get("usage")
                .unwrap()
                .get("completion_tokens")
                .unwrap()
                .as_u64()
                .unwrap()
        };
        assert_eq!(n(&b1), 2, "first response answers the first request");
        assert_eq!(n(&b2), 3, "second response answers the second request");
        gw.shutdown();
    }
}
