//! Golden parity suite for the shared incremental barrier-step engine.
//!
//! `sim::reference::reference_run` is a frozen copy of the pre-refactor
//! `sim::Simulator::run` loop (the naive O(G·B)-per-step cycle:
//! re-summed loads, per-active predictor calls, linear complete/drift
//! scans, fresh view allocations), with one deliberate amendment made
//! in lockstep with the engine (PR 3): the policy-facing drift
//! forecast is age-indexed (see `sim::reference` docs).  It is the
//! golden oracle: the refactored `Simulator` — a thin driver over
//! `sim::engine` — must reproduce its reports (avg_imbalance,
//! wall_time_s, total_workload, energy, TPOT, completion records) to
//! within 1e-9 relative on fixed seeds, across policies, drift models,
//! and the deterministic predictors (Oracle / WindowOracle /
//! Pessimistic).  `Predictor::Noisy` is intentionally out of scope: the
//! engine reorders/elides its rng draws (slot-order views, predictor
//! calls skipped for non-lookahead policies), so noisy runs are a
//! different — equally valid — random realization by design (see
//! `sim::reference` docs).
//!
//! A second suite checks offline-vs-gateway parity: the online
//! `SimBackend` scheduler (the other driver of the same engine) must
//! produce identical virtual-time completions for a sequentially
//! submitted trace.

use bfio_serve::config::SimConfig;
use bfio_serve::gateway::backend::{Backend, CompletionRequest};
use bfio_serve::gateway::sim::{SimBackend, SimBackendConfig};
use bfio_serve::metrics::Report;
use bfio_serve::sim::predictor::Predictor;
use bfio_serve::sim::reference::reference_run;
use bfio_serve::sim::Simulator;
use bfio_serve::util::rng::Rng;
use bfio_serve::workload::adversarial::overloaded_trace;
use bfio_serve::workload::longbench::LongBenchLike;
use bfio_serve::workload::{
    generate_trace, ArrivalProcess, Drift, GeometricSampler, Request,
};
use std::time::Duration;

// ---------------------------------------------------------------------
// Comparison helpers
// ---------------------------------------------------------------------

const TOL: f64 = 1e-9;

fn close(a: f64, b: f64, what: &str) {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() <= TOL * scale,
        "{what}: engine {a:.17e} vs reference {b:.17e}"
    );
}

fn assert_reports_match(engine: &Report, golden: &Report, label: &str) {
    assert_eq!(engine.steps, golden.steps, "{label}: recorded steps");
    assert_eq!(engine.completed, golden.completed, "{label}: completed");
    close(engine.avg_imbalance, golden.avg_imbalance, "avg_imbalance");
    close(engine.wall_time_s, golden.wall_time_s, "wall_time_s");
    close(engine.total_workload, golden.total_workload, "total_workload");
    close(engine.total_tokens, golden.total_tokens, "total_tokens");
    close(engine.throughput_tps, golden.throughput_tps, "throughput_tps");
    close(engine.tpot_s, golden.tpot_s, "tpot_s");
    close(engine.tpot_p99_s, golden.tpot_p99_s, "tpot_p99_s");
    close(
        engine.mean_queue_wait_s,
        golden.mean_queue_wait_s,
        "mean_queue_wait_s",
    );
    close(
        engine.mean_idle_fraction,
        golden.mean_idle_fraction,
        "mean_idle_fraction",
    );
    close(engine.sync_energy_j, golden.sync_energy_j, "sync_energy_j");
    close(engine.total_energy_j, golden.total_energy_j, "total_energy_j");
    close(engine.eta_sum, golden.eta_sum, "eta_sum");
    close(engine.imb_tot, golden.imb_tot, "imb_tot");

    // Completion records: same multiset of requests, same placements and
    // timings (bucket completion reorders within a step, so sort by id).
    let mut a = engine.completions.clone();
    let mut b = golden.completions.clone();
    assert_eq!(a.len(), b.len(), "{label}: completion record count");
    a.sort_by_key(|r| r.id);
    b.sort_by_key(|r| r.id);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id, "{label}: completion ids");
        assert_eq!(x.worker, y.worker, "{label}: id {} placed differently", x.id);
        assert_eq!(x.tokens, y.tokens, "{label}: id {} tokens", x.id);
        close(x.arrival_clock, y.arrival_clock, "arrival_clock");
        close(x.admit_clock, y.admit_clock, "admit_clock");
        close(x.finish_clock, y.finish_clock, "finish_clock");
    }
}

fn check_parity(cfg: SimConfig, predictor: Predictor, trace: &[Request], policy: &str) {
    let golden = reference_run(
        &cfg,
        &predictor,
        trace,
        &mut *bfio_serve::policies::by_name(policy).unwrap(),
    );
    let sim = Simulator::new(cfg).with_predictor(predictor);
    let got = sim.run(trace, &mut *bfio_serve::policies::by_name(policy).unwrap());

    assert_reports_match(&got.report, &golden.report, policy);
    assert_eq!(got.completed, golden.completed, "{policy}: completed");
    assert_eq!(got.admitted, golden.admitted, "{policy}: admitted");
    assert_eq!(
        got.leftover_waiting, golden.leftover_waiting,
        "{policy}: leftover"
    );
    assert_eq!(got.steps, golden.steps, "{policy}: executed steps");
}

fn geometric_trace(seed: u64) -> Vec<Request> {
    let sampler = GeometricSampler::new(5, 200, 0.2);
    let mut rng = Rng::new(seed);
    overloaded_trace(&sampler, 4, 8, 60, 2.0, &mut rng)
}

fn drain_cfg(drift: Drift) -> SimConfig {
    SimConfig {
        g: 4,
        b: 8,
        seed: 11,
        max_steps: 0,
        warmup_steps: 0,
        record_completions: true,
        drift,
        ..SimConfig::default()
    }
}

// ---------------------------------------------------------------------
// Golden tests: engine vs frozen reference
// ---------------------------------------------------------------------

#[test]
fn golden_parity_fcfs_jsq_on_drained_geometric() {
    let trace = geometric_trace(41);
    for policy in ["fcfs", "jsq", "rr", "least"] {
        check_parity(drain_cfg(Drift::Unit), Predictor::Oracle, &trace, policy);
    }
}

#[test]
fn golden_parity_bfio_myopic_and_lookahead() {
    let trace = geometric_trace(42);
    for policy in ["bfio:0", "bfio:20"] {
        check_parity(drain_cfg(Drift::Unit), Predictor::Oracle, &trace, policy);
    }
}

#[test]
fn golden_parity_longbench_capped_with_warmup() {
    let sampler = LongBenchLike::paper();
    let mut rng = Rng::new(7);
    let trace = overloaded_trace(&sampler, 8, 12, 150, 3.0, &mut rng);
    let cfg = SimConfig {
        g: 8,
        b: 12,
        seed: 7,
        max_steps: 150,
        warmup_steps: 30,
        record_completions: true,
        ..SimConfig::default()
    };
    for policy in ["fcfs", "bfio:40"] {
        check_parity(cfg.clone(), Predictor::Oracle, &trace, policy);
    }
}

#[test]
fn golden_parity_window_oracle_and_pessimistic_predictors() {
    // Neither predictor draws randomness, so the rng streams stay
    // aligned even though the engine skips predictor calls for
    // non-lookahead policies.  (Noisy is out of scope — see the module
    // docs.)
    let trace = geometric_trace(43);
    check_parity(
        drain_cfg(Drift::Unit),
        Predictor::WindowOracle,
        &trace,
        "bfio:12",
    );
    check_parity(
        drain_cfg(Drift::Unit),
        Predictor::Pessimistic,
        &trace,
        "bfio:12",
    );
}

#[test]
fn golden_parity_zero_and_const_drift() {
    let trace = geometric_trace(44);
    check_parity(drain_cfg(Drift::Zero), Predictor::Oracle, &trace, "fcfs");
    check_parity(
        drain_cfg(Drift::Const(0.5)),
        Predictor::Oracle,
        &trace,
        "bfio:0",
    );
}

#[test]
fn golden_parity_age_varying_cycle_drift() {
    // Cycle drift is not a constant increment: this exercises the
    // engine's per-worker age histograms AND the age-indexed lookahead
    // forecast (PR 3) — both the engine and the oracle forecast each
    // active from its own age, so parity holds for lookahead policies
    // under age-varying drift too.
    let trace = geometric_trace(45);
    check_parity(
        drain_cfg(Drift::Cycle(vec![1.0, 0.0])),
        Predictor::Oracle,
        &trace,
        "bfio:8",
    );
    check_parity(
        drain_cfg(Drift::Cycle(vec![2.0, 0.5, 1.0])),
        Predictor::Oracle,
        &trace,
        "jsq",
    );
}

#[test]
fn golden_parity_age_varying_decay_drift_with_lookahead() {
    // Decay drift under a lookahead policy: every request's forecast
    // depends on its individual age, the regime the age-indexed fix is
    // for.
    let trace = geometric_trace(46);
    check_parity(
        drain_cfg(Drift::Decay { d0: 2.0, rate: 0.8 }),
        Predictor::Oracle,
        &trace,
        "bfio:12",
    );
    check_parity(
        drain_cfg(Drift::Decay { d0: 1.0, rate: 0.5 }),
        Predictor::WindowOracle,
        &trace,
        "bfio:6",
    );
}

#[test]
fn idle_gaps_skipped_without_changing_outcomes() {
    // A trace with a dead period: the engine jumps the gap (no empty
    // barrier steps, no wall-clock charged) while the reference
    // simulates it.  Scheduling outcomes — completions, placements,
    // policy-independent workload — must still agree exactly; only the
    // idle-step accounting differs.
    let sampler = GeometricSampler::new(5, 50, 0.5);
    let arrivals = ArrivalProcess::Fixed { per_step: 2, initial_backlog: 6 };
    let mut rng = Rng::new(9);
    let mut trace = generate_trace(&sampler, &arrivals, 10, &mut rng);
    let burst = generate_trace(&sampler, &arrivals, 5, &mut rng);
    let base = 500u64; // far beyond the first batch's drain time
    let next_id = trace.len() as u64;
    for (i, r) in burst.into_iter().enumerate() {
        trace.push(Request {
            id: next_id + i as u64,
            arrival_step: base + r.arrival_step,
            ..r
        });
    }

    let cfg = drain_cfg(Drift::Unit);
    let golden = reference_run(
        &cfg,
        &Predictor::Oracle,
        &trace,
        &mut *bfio_serve::policies::by_name("fcfs").unwrap(),
    );
    let got = Simulator::new(cfg)
        .run(&trace, &mut *bfio_serve::policies::by_name("fcfs").unwrap());

    assert_eq!(got.completed, golden.completed);
    assert_eq!(got.completed as usize, trace.len());
    close(
        got.report.total_workload,
        golden.report.total_workload,
        "total_workload",
    );
    // the reference executed the idle gap; the engine skipped it
    assert!(golden.steps >= base, "reference walks the gap: {}", golden.steps);
    assert!(got.steps < base, "engine skips the gap: {}", got.steps);
    assert!(got.report.wall_time_s < golden.report.wall_time_s);
    // identical placements and timings for every request
    let mut a = got.report.completions.clone();
    let mut b = golden.report.completions.clone();
    a.sort_by_key(|r| r.id);
    b.sort_by_key(|r| r.id);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!((x.id, x.worker, x.tokens), (y.id, y.worker, y.tokens));
    }
}

// ---------------------------------------------------------------------
// Offline simulator vs online gateway scheduler on the same trace
// ---------------------------------------------------------------------

/// Sequentially round-tripped requests through the live `SimBackend`
/// must reproduce the offline simulator's virtual-time records exactly:
/// both are thin drivers over the same engine, and with one request in
/// flight at a time there is no intake nondeterminism.
fn gateway_offline_parity(policy: &str) {
    let g = 3;
    let b = 2;
    let n = 12u64;
    // varied sizes; arrival i lands exactly when request i-1 completes
    let spec: Vec<(usize, u32)> = (0..n)
        .map(|i| ((3 + (7 * i) % 11) as usize, (1 + (3 * i) % 5) as u32))
        .collect();
    let mut arrival = 0u64;
    let trace: Vec<Request> = spec
        .iter()
        .enumerate()
        .map(|(i, &(prefill, o))| {
            let r = Request {
                id: i as u64,
                arrival_step: arrival,
                prefill: prefill as f64,
                decode_len: u64::from(o),
            };
            arrival += u64::from(o);
            r
        })
        .collect();

    let sim_cfg = SimConfig {
        g,
        b,
        seed: 0,
        max_steps: 0,
        warmup_steps: 0,
        record_completions: true,
        ..SimConfig::default()
    };
    let offline = Simulator::new(sim_cfg.clone())
        .run(&trace, &mut *bfio_serve::policies::by_name(policy).unwrap());
    let mut records = offline.report.completions.clone();
    records.sort_by_key(|r| r.id);
    assert_eq!(records.len(), n as usize);

    let be = SimBackend::new(SimBackendConfig {
        g,
        b,
        policy: policy.to_string(),
        step_delay: Duration::ZERO,
        batch_window: Duration::ZERO,
        ..SimBackendConfig::default()
    })
    .unwrap();
    for (i, &(prefill, o)) in spec.iter().enumerate() {
        let c = be
            .complete(CompletionRequest {
                id: i as u64,
                prompt_tokens: vec![1; prefill],
                max_tokens: o,
            })
            .unwrap();
        let r = &records[i];
        assert_eq!(c.worker, r.worker, "{policy}: id {i} placed differently");
        assert_eq!(u64::from(c.n_tokens), r.tokens);
        let tpot_off = (r.finish_clock - r.admit_clock) / r.tokens as f64;
        close(c.tpot_s, tpot_off, "tpot_s");
        close(c.latency_s, r.finish_clock - r.arrival_clock, "latency_s");
        close(
            c.queue_wait_s,
            (r.admit_clock - r.arrival_clock).max(0.0),
            "queue_wait_s",
        );
    }

    // aggregate stats line up with the offline report (warmup 0)
    let st = be.stats();
    assert_eq!(st.completed, n);
    assert_eq!(st.admitted, n);
    assert_eq!(st.steps, offline.steps);
    assert_eq!(st.total_tokens as f64, offline.report.total_tokens);
    close(st.clock_s, offline.report.wall_time_s, "clock vs wall_time");
    close(st.avg_imbalance, offline.report.avg_imbalance, "avg_imbalance");
    close(st.energy_j, offline.report.total_energy_j, "energy");
}

#[test]
fn gateway_matches_offline_round_robin() {
    gateway_offline_parity("rr");
}

#[test]
fn gateway_matches_offline_least_loaded() {
    gateway_offline_parity("least");
}
