//! SSE streaming end-to-end: framing, stream/non-stream byte equality,
//! TTFT, shedding under overload, mid-stream disconnects, and the
//! shutdown drain — all against the epoll reactor with the sim backend
//! (virtual time, no GPUs).

#![cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]

use std::io::{BufRead, BufReader, Write};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use bfio_serve::gateway::http as ghttp;
use bfio_serve::gateway::loadgen::{self, LoadGenConfig};
use bfio_serve::gateway::sim::{SimBackend, SimBackendConfig};
use bfio_serve::gateway::{Gateway, GatewayConfig};
use bfio_serve::util::json::Json;
use bfio_serve::util::stats;

fn boot(
    step_delay_ms: u64,
    batch_window_ms: u64,
    cfg_mut: impl FnOnce(&mut GatewayConfig),
) -> (Gateway, String) {
    let backend = SimBackend::new(SimBackendConfig {
        g: 4,
        b: 4,
        policy: "fcfs".to_string(),
        step_delay: Duration::from_millis(step_delay_ms),
        batch_window: Duration::from_millis(batch_window_ms),
        ..SimBackendConfig::default()
    })
    .unwrap();
    let mut cfg = GatewayConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 4,
        ..GatewayConfig::default()
    };
    cfg_mut(&mut cfg);
    let gw = Gateway::spawn(cfg, Arc::new(backend)).unwrap();
    let a = gw.addr.to_string();
    (gw, a)
}

#[test]
fn sse_framing_and_stream_nonstream_byte_equality() {
    // Two identical fresh gateways: request ids start at 0 on both, and
    // the sim backend's tokens are a pure function of the request id —
    // so the streamed deltas must concatenate to the exact non-streamed
    // text for the same request.
    let (gw_a, a) = boot(0, 0, |_| {});
    let (gw_b, b) = boot(0, 0, |_| {});

    let body = r#"{"prompt": [1, 2, 3], "max_tokens": 4}"#;
    let r = ghttp::http_call(&a, "POST", "/v1/completions", Some(body)).unwrap();
    assert_eq!(r.status, 200, "body: {}", r.body_str().unwrap_or(""));
    let v = Json::parse(r.body_str().unwrap()).unwrap();
    let plain_text = v
        .get("choices")
        .unwrap()
        .idx(0)
        .unwrap()
        .get("text")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();

    let stream_body = r#"{"prompt": [1, 2, 3], "max_tokens": 4, "stream": true}"#;
    let res = ghttp::sse_call(&b, "/v1/completions", stream_body).unwrap();
    assert_eq!(res.status, 200);
    assert!(res.done, "stream must end with data: [DONE]");
    // One chunk per generated token, plus the final usage chunk.
    assert_eq!(res.events.len(), 4 + 1, "events: {:?}", res.events);

    let mut streamed = String::new();
    for (payload, _) in &res.events[..res.events.len() - 1] {
        let ev = Json::parse(payload).unwrap();
        assert_eq!(
            ev.get("object").unwrap().as_str().unwrap(),
            "text_completion.chunk"
        );
        let choice = ev.get("choices").unwrap().idx(0).unwrap();
        assert_eq!(choice.get("finish_reason"), Some(&Json::Null));
        streamed.push_str(choice.get("text").unwrap().as_str().unwrap());
    }
    assert_eq!(
        streamed, plain_text,
        "streamed deltas must concatenate to the non-streamed text"
    );

    // The final pre-[DONE] chunk: empty text, finish_reason, usage.
    let (last, _) = res.events.last().unwrap();
    let fin = Json::parse(last).unwrap();
    let choice = fin.get("choices").unwrap().idx(0).unwrap();
    assert_eq!(choice.get("text").unwrap().as_str().unwrap(), "");
    assert_eq!(choice.get("finish_reason").unwrap().as_str().unwrap(), "length");
    assert_eq!(
        fin.get("usage")
            .unwrap()
            .get("completion_tokens")
            .unwrap()
            .as_u64()
            .unwrap(),
        4
    );
    assert!(fin.get("bfio").unwrap().get("worker").is_some());
    gw_a.shutdown();
    gw_b.shutdown();
}

#[test]
fn loadgen_stream_reports_ttft_below_total_latency() {
    let (gw, a) = boot(3, 5, |_| {});
    let cfg = LoadGenConfig {
        authority: a.clone(),
        concurrency: 4,
        requests: 8,
        prompt_tokens: 8,
        max_tokens: 8,
        seed: 7,
        stream: true,
        ..LoadGenConfig::default()
    };
    let res = loadgen::run(&cfg).unwrap();
    assert_eq!(res.completed, 8, "sheds={} errors={}", res.sheds, res.errors);
    assert_eq!(res.errors, 0);
    assert_eq!(res.ttfts_s.len(), 8, "every streamed request measures TTFT");
    let mean_ttft = stats::mean(&res.ttfts_s);
    let mean_lat = stats::mean(&res.latencies_s);
    assert!(
        mean_ttft < mean_lat,
        "first token must land before the full response (ttft {mean_ttft} vs latency {mean_lat})"
    );
    assert!(
        loadgen::prom_value(&res.metrics_after, "bfio_gateway_streams_total").unwrap() >= 8.0,
        "stream counter tracks SSE completions"
    );
    gw.shutdown();
}

#[test]
fn overload_sheds_429_with_retry_after() {
    // Watermark of 1 in-flight completion; a slow backend holds it for
    // ~500ms, so the follow-up burst must shed with 429 + Retry-After.
    let (gw, a) = boot(20, 0, |c| c.max_inflight = 1);
    let a2 = a.clone();
    let first = std::thread::spawn(move || {
        ghttp::sse_call(
            &a2,
            "/v1/completions",
            r#"{"prompt": [1, 2], "max_tokens": 25, "stream": true}"#,
        )
        .unwrap()
    });
    // Let the first stream get admitted, then burst.
    std::thread::sleep(Duration::from_millis(100));
    let mut sheds = 0;
    for _ in 0..3 {
        let r = ghttp::sse_call(
            &a,
            "/v1/completions",
            r#"{"prompt": [3, 4], "max_tokens": 2, "stream": true}"#,
        )
        .unwrap();
        if r.status == 429 {
            assert!(
                r.headers
                    .iter()
                    .any(|(k, _)| k.eq_ignore_ascii_case("retry-after")),
                "shed must carry Retry-After"
            );
            sheds += 1;
        }
    }
    assert!(sheds >= 1, "burst past the watermark must shed");
    let first = first.join().unwrap();
    assert_eq!(first.status, 200);
    assert!(first.done);

    let m = ghttp::http_call(&a, "GET", "/metrics", None).unwrap();
    let text = m.body_str().unwrap();
    assert!(
        loadgen::prom_value(text, "bfio_gateway_shed_total").unwrap() >= sheds as f64,
        "shed counter reflects 429s"
    );
    gw.shutdown();
}

#[test]
fn mid_stream_disconnect_frees_the_connection_and_gateway_keeps_serving() {
    let (gw, a) = boot(10, 0, |c| c.max_inflight = 2);
    {
        // Start a long stream, read only its first delta, then drop the
        // socket mid-stream.
        let mut s = std::net::TcpStream::connect(a.as_str()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let body = r#"{"prompt": [9, 9], "max_tokens": 100, "stream": true}"#;
        write!(
            s,
            "POST /v1/completions HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        loop {
            line.clear();
            assert!(r.read_line(&mut line).unwrap() > 0, "stream ended early");
            if line.starts_with("data:") {
                break;
            }
        }
        // Dropping the socket here aborts the stream client-side.
    }
    // The gateway must keep serving new completions immediately.
    let r = ghttp::http_call(
        &a,
        "POST",
        "/v1/completions",
        Some(r#"{"prompt": [1], "max_tokens": 2}"#),
    )
    .unwrap();
    assert_eq!(r.status, 200);
    // And the dead connection is reaped: the open-connections gauge
    // falls back to just the scraping connection itself.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let m = ghttp::http_call(&a, "GET", "/metrics", None).unwrap();
        let open =
            loadgen::prom_value(m.body_str().unwrap(), "bfio_gateway_open_connections")
                .unwrap();
        if open <= 1.0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "aborted stream connection was never reaped (open={open})"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    gw.shutdown();
}

#[test]
fn shutdown_drains_in_flight_requests_without_losing_responses() {
    let (gw, a) = boot(5, 0, |_| {});
    let n = 6usize;
    let barrier = Arc::new(Barrier::new(n + 1));
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let a = a.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let body =
                    format!(r#"{{"prompt": [7, {i}], "max_tokens": 40}}"#);
                let mut s = std::net::TcpStream::connect(a.as_str()).unwrap();
                s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                write!(
                    s,
                    "POST /v1/completions HTTP/1.1\r\nConnection: close\r\n\
                     Content-Length: {}\r\n\r\n{}",
                    body.len(),
                    body
                )
                .unwrap();
                s.flush().unwrap();
                // Request fully on the wire — now let main shut down.
                barrier.wait();
                let mut r = BufReader::new(s);
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                let status: u16 =
                    line.split_whitespace().nth(1).unwrap().parse().unwrap();
                status
            })
        })
        .collect();
    barrier.wait();
    // Give the reactor a beat to accept every queued connection (the
    // drain closes the listener, discarding unaccepted backlog), then
    // shut down with all requests on the wire: each must be answered
    // (200 if in flight, 503 if it arrived behind the drain), none
    // dropped on the floor.
    std::thread::sleep(Duration::from_millis(50));
    gw.shutdown();
    let statuses: Vec<u16> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(
        statuses.iter().all(|s| *s == 200 || *s == 503),
        "drain must answer every accepted request: {statuses:?}"
    );
    assert!(
        statuses.iter().any(|s| *s == 200),
        "at least one in-flight request completes through the drain: {statuses:?}"
    );
}
