//! Journal + counterfactual replay coverage:
//!
//! * pinned replay reproduces the recorded result bit-exactly (ints
//!   exact, floats ≤ 1e-9 relative) across the tier-1 router panel ×
//!   round-execution threads {1, 8} × faults on/off, with zero decision
//!   divergence;
//! * a counterfactual whose overrides equal the recorded run (same
//!   router spec, same speeds) re-decides every route and still lands
//!   on the same trajectory — the replay event reconstruction is
//!   faithful, not just the decision pinning;
//! * a genuinely different counterfactual router completes and
//!   conserves work over the same journaled arrivals;
//! * `--no-faults` on a faulted journal replays a clean run;
//! * binary and JSONL journal files round-trip through disk and still
//!   replay exactly;
//! * a ring that evicted events refuses to replay.

use bfio_serve::fault::FaultPlan;
use bfio_serve::fleet::{run_fleet_recorded, FleetConfig};
use bfio_serve::obs::{replay_journal, Journal, ReplayOptions};
use bfio_serve::util::rng::Rng;
use bfio_serve::workload::{
    generate_trace, ArrivalProcess, GeometricSampler, Request,
};

fn trace_of(seed: u64, per_step: usize, backlog: usize, steps: u64) -> Vec<Request> {
    let mut sampler = GeometricSampler::new(5, 80, 0.25);
    sampler.o_cap = 12;
    let arrivals = ArrivalProcess::Fixed { per_step, initial_backlog: backlog };
    let mut rng = Rng::new(seed);
    generate_trace(&sampler, &arrivals, steps, &mut rng)
}

fn cfg_of(replicas: usize, seed: u64, threads: usize) -> FleetConfig {
    FleetConfig {
        seed,
        threads,
        ..FleetConfig::uniform(replicas, 2, 2, "bfio:8")
    }
}

/// Record one run and hand back its journal (cloned out of the shared
/// handle, as `bfio replay` sees it after `Journal::load`).
fn record(
    router: &str,
    threads: usize,
    faults: Option<&FaultPlan>,
    cap: usize,
) -> Journal {
    let cfg = cfg_of(3, 11, threads);
    let trace = trace_of(42, 2, 6, 30);
    let (_res, journal) =
        run_fleet_recorded(&cfg, router, &trace, &[], None, faults, cap).unwrap();
    let j = journal.lock().unwrap().clone();
    j
}

fn assert_pinned_exact(what: &str, journal: &Journal) {
    let outcome = replay_journal(journal, &ReplayOptions::default()).unwrap();
    assert!(outcome.pinned, "{what}: default options must be pinned");
    assert_eq!(outcome.forced, 0, "{what}: forced decisions in pinned replay");
    assert_eq!(outcome.extra, 0, "{what}: unrecorded decisions in pinned replay");
    let rec = journal.result.as_ref().expect("recorded result");
    let diff = rec.diff(&outcome.summary());
    assert!(diff.is_empty(), "{what}: pinned replay diverged:\n  {}", diff.join("\n  "));
}

#[test]
fn pinned_replay_reproduces_every_router() {
    for router in ["wrr", "low", "powd:2", "bfio2", "bfio2h"] {
        let journal = record(router, 1, None, 1 << 16);
        assert_pinned_exact(&format!("router {router}"), &journal);
    }
}

#[test]
fn pinned_replay_reproduces_faulted_runs() {
    let plan = FaultPlan::parse("crash@6:r0,recover@40:r0").unwrap();
    for router in ["low", "bfio2"] {
        let journal = record(router, 1, Some(&plan), 1 << 16);
        let rec = journal.result.as_ref().unwrap();
        assert!(rec.crashes > 0, "plan injected nothing");
        assert_pinned_exact(&format!("faulted {router}"), &journal);
    }
}

#[test]
fn pinned_replay_is_thread_invariant() {
    // Journal recorded serially, replayed with 8 round-execution
    // threads: a threads-only override keeps the replay pinned and the
    // result identical (parallel ≡ serial parity).
    let journal = record("bfio2", 1, None, 1 << 16);
    let opts = ReplayOptions { threads: Some(8), ..ReplayOptions::default() };
    assert!(opts.is_pinned());
    let outcome = replay_journal(&journal, &opts).unwrap();
    assert_eq!(outcome.forced + outcome.extra, 0);
    let diff = journal.result.as_ref().unwrap().diff(&outcome.summary());
    assert!(diff.is_empty(), "threads=8 replay diverged:\n  {}", diff.join("\n  "));
    // And a journal recorded in parallel replays exactly too.
    let journal8 = record("bfio2", 8, None, 1 << 16);
    assert_pinned_exact("recorded with threads=8", &journal8);
}

#[test]
fn identical_override_counterfactual_ties_pinned() {
    // Re-deciding every route with the *same* router spec (and the
    // recorded speeds) must land on the recorded trajectory: the
    // counterfactual path reconstructs the same arrivals, faults, and
    // lifecycle stream the live run consumed.
    let plan = FaultPlan::parse("crash@6:r0,recover@40:r0").unwrap();
    let journal = record("low", 1, Some(&plan), 1 << 16);
    let opts = ReplayOptions {
        router: Some(journal.config.router.clone()),
        speeds: Some(journal.config.fleet.speeds.clone()),
        ..ReplayOptions::default()
    };
    assert!(!opts.is_pinned());
    let outcome = replay_journal(&journal, &opts).unwrap();
    assert!(!outcome.pinned);
    let diff = journal.result.as_ref().unwrap().diff(&outcome.summary());
    assert!(
        diff.is_empty(),
        "identical-override counterfactual diverged:\n  {}",
        diff.join("\n  ")
    );
}

#[test]
fn different_router_counterfactual_conserves_work() {
    let journal = record("low", 1, None, 1 << 16);
    let opts = ReplayOptions {
        router: Some("wrr".to_string()),
        ..ReplayOptions::default()
    };
    let outcome = replay_journal(&journal, &opts).unwrap();
    let sum = outcome.summary();
    let rec = journal.result.as_ref().unwrap();
    assert_eq!(sum.submitted, rec.submitted, "same journaled arrivals");
    assert_eq!(
        sum.completed + sum.shed + sum.leftover_waiting,
        sum.submitted,
        "counterfactual stranded work"
    );
    assert!(sum.completed > 0);
    assert!(sum.router.to_lowercase().contains("wrr"), "router {:?}", sum.router);
}

#[test]
fn no_faults_counterfactual_replays_clean() {
    let plan = FaultPlan::parse("crash@6:r0,recover@40:r0").unwrap();
    let journal = record("low", 1, Some(&plan), 1 << 16);
    assert!(journal.result.as_ref().unwrap().crashes > 0);
    let opts = ReplayOptions { no_faults: true, ..ReplayOptions::default() };
    let outcome = replay_journal(&journal, &opts).unwrap();
    let sum = outcome.summary();
    assert_eq!(sum.crashes + sum.stalls + sum.recoveries, 0, "faults leaked");
    assert_eq!(sum.shed, 0);
    assert_eq!(sum.completed + sum.leftover_waiting, sum.submitted);
}

#[test]
fn journal_files_round_trip_and_replay() {
    let plan = FaultPlan::parse("crash@6:r0,recover@40:r0").unwrap();
    let journal = record("bfio2", 1, Some(&plan), 1 << 16);
    for ext in ["bin", "jsonl"] {
        let path = std::env::temp_dir().join(format!("bfio_replay_rt.{ext}"));
        journal.save(&path).unwrap();
        let loaded = Journal::load(&path).unwrap();
        assert_eq!(loaded.ring.len(), journal.ring.len(), "{ext}: event count");
        assert_eq!(loaded.route_seq, journal.route_seq, "{ext}: route_seq");
        assert_eq!(loaded.config.router, journal.config.router, "{ext}: router");
        assert_eq!(
            loaded.result.as_ref().map(|r| r.completed),
            journal.result.as_ref().map(|r| r.completed),
            "{ext}: recorded result"
        );
        assert_pinned_exact(&format!("loaded from .{ext}"), &loaded);
    }
}

#[test]
fn evicting_ring_refuses_replay() {
    // A cap far below the event volume forces evictions; the journal
    // still records (bounded memory) but replay must refuse rather than
    // reconstruct a partial trajectory.
    let journal = record("low", 1, None, 8);
    assert!(journal.ring.dropped() > 0, "cap 8 evicted nothing");
    let err = replay_journal(&journal, &ReplayOptions::default()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("journal-cap") || msg.to_lowercase().contains("evict"), "{msg}");
}
