//! Fleet sweep: R×G replicas under every tier-1 router versus the
//! monolithic R·G-worker group on the same overloaded trace, across a
//! range of replica counts — the machine-readable evidence for the
//! two-level routing tier.
//!
//! Emits `BENCH_fleet.json` (per-(R, router) imbalance, cross-replica
//! clock ratio, TPOT, throughput, energy, plus ratios against the
//! monolith).  `-- --smoke` runs a small sweep for CI; `-- --out PATH`
//! overrides the output file (CI uses it to regenerate the canonical
//! file with measured numbers).

use bfio_serve::experiments::fleet::{
    bench_json, rows_to_json, run_fleet_rows, FleetScale,
};
use std::time::Instant;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let out_override = argv
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let rs: &[usize] = if smoke { &[2] } else { &[2, 4, 8] };
    let g = 16usize;
    let b = 8usize;
    let steps: u64 = if smoke { 60 } else { 200 };
    let routers: Vec<String> = ["wrr", "low", "powd:2", "bfio2", "bfio2h"]
        .iter()
        .map(|r| r.to_string())
        .collect();

    println!(
        "fleet sweep (G={g}, B={b}, {steps} steps): R replicas vs monolithic R·G workers,\n\
         each router timed serial (--threads 1) vs parallel (all cores)"
    );
    let t_all = Instant::now();
    let mut sweep = Vec::new();
    for &r in rs {
        let scale = FleetScale::new(r, g, b, steps);
        let (rows, mono) =
            run_fleet_rows(&scale, &routers, &[]).expect("fleet run");
        println!(
            "R={r}: monolith imb {:.3e}; per router (imb, clk, tok/s, ser ms, par ms, speedup):",
            mono.avg_imbalance
        );
        for row in &rows {
            println!(
                "  {:<16} {:>12.3e} {:>6.3} {:>10.1} {:>8.1} {:>8.1} {:>6.2}x",
                row.router,
                row.avg_imbalance,
                row.clock_ratio,
                row.throughput_tps,
                row.serial_run_ms,
                row.parallel_run_ms,
                row.speedup
            );
        }
        sweep.push(rows_to_json(&scale, &rows, &mono));
    }
    let total_ms = t_all.elapsed().as_secs_f64() * 1e3;
    println!("total {total_ms:.0} ms");

    // Same document shape as `bfio fleet` (per-scale g/b/steps live in
    // each sweep entry).
    let json = bench_json(smoke, false, total_ms, sweep);
    let default_path = if smoke { "BENCH_fleet_smoke.json" } else { "BENCH_fleet.json" };
    let path = out_override.as_deref().unwrap_or(default_path);
    match std::fs::write(path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
