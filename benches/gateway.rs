//! Gateway transport sweep: epoll reactor vs the legacy blocking
//! thread pool over real sockets (sim backend, virtual time — no GPUs
//! needed), one SSE-streamed loadgen run per connection count.
//!
//! Emits `BENCH_gateway.json` (per-connection-count completed/shed
//! counts, req/s, tok/s, TTFT and TPOT p50/p99 for both transports,
//! plus the `reactor_ge_pool_at_max` verdict CI gates on).
//! `-- --smoke` runs a small sweep for CI; `-- --out PATH` overrides
//! the output file (CI uses it to regenerate the canonical file with
//! measured numbers).

use bfio_serve::experiments::gateway::{gateway_bench, GatewayScale};

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let out_override = argv
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let scale = if smoke { GatewayScale::smoke() } else { GatewayScale::full() };
    let conns: &[usize] = if smoke { &[1, 8, 32] } else { &[1, 4, 16, 64] };

    let json = gateway_bench(&scale, conns, smoke).expect("gateway bench");
    let default_path =
        if smoke { "BENCH_gateway_smoke.json" } else { "BENCH_gateway.json" };
    let path = out_override.as_deref().unwrap_or(default_path);
    match std::fs::write(path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
