//! Figs 10/11 regeneration with timing: the G-sweep that demonstrates
//! super-linear FCFS imbalance growth vs bounded BF-IO.

use bfio_serve::experiments::scaling::scaling_sweep;
use bfio_serve::experiments::ExpScale;
use std::time::Instant;

fn main() {
    let scale = ExpScale {
        g: 0,
        b: 24,
        steps: 300,
        seed: 7,
        out_dir: "results".into(),
    };
    let t0 = Instant::now();
    let rows = scaling_sweep(&scale, &[16, 32, 64, 96, 128]);
    let dt = t0.elapsed().as_secs_f64();
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    println!(
        "\nimbalance ratio grows {:.2}x -> {:.2}x across the sweep ({:.2}s total)",
        first.fcfs_imb / first.bfio_imb,
        last.fcfs_imb / last.bfio_imb,
        dt
    );
}
