//! Figs 10/11 regeneration with timing: the G-sweep that demonstrates
//! super-linear FCFS imbalance growth vs bounded BF-IO — and the perf
//! trajectory of the barrier-step engine itself.
//!
//! For each G the sweep runs FCFS and BF-IO(40) twice: once through the
//! incremental `sim::engine` (via `Simulator::run`) and once through the
//! frozen pre-refactor loop (`sim::reference::reference_run`), so the
//! engine's speedup over the old O(G·B)-per-step cycle is measured
//! directly, with the two paths' imbalances cross-checked on the spot.
//!
//! Emits `BENCH_scaling.json` (per-G wall-clock ms per policy per path,
//! speedup, imbalance ratios) so the trajectory is machine-readable and
//! comparable across PRs.  `-- --smoke` runs a small-G sweep for CI
//! (written to `BENCH_scaling_smoke.json` so the full-sweep evidence is
//! not clobbered); `-- --out PATH` overrides the output file — CI uses
//! `--smoke --out BENCH_scaling.json` to replace the checked-in schema
//! placeholder with measured (smoke-scale) timings.

use bfio_serve::config::SimConfig;
use bfio_serve::policies::by_name;
use bfio_serve::sim::predictor::Predictor;
use bfio_serve::sim::reference::reference_run;
use bfio_serve::sim::Simulator;
use bfio_serve::util::json::{arr, num, obj, s, Json};
use bfio_serve::util::rng::Rng;
use bfio_serve::workload::adversarial::overloaded_trace;
use bfio_serve::workload::longbench::LongBenchLike;
use std::time::Instant;

fn ms(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let out_override = argv
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let gs: &[usize] = if smoke { &[4, 8] } else { &[16, 32, 64, 96, 128] };
    let steps: u64 = if smoke { 100 } else { 300 };
    let b = 24usize;
    let seed = 7u64;
    let sampler = LongBenchLike::paper();

    println!("scaling sweep (B={b}, {steps} steps): engine vs pre-refactor reference loop");
    println!(
        "{:>5} {:>12} {:>12} {:>12} {:>12} {:>9} {:>10}",
        "G", "eng_fcfs_ms", "eng_bfio_ms", "ref_fcfs_ms", "ref_bfio_ms", "speedup", "imb_ratio"
    );

    let t_all = Instant::now();
    let mut rows_json = Vec::new();
    let mut eng_total = 0.0f64;
    let mut ref_total = 0.0f64;
    let mut first_ratio = 0.0f64;
    let mut last_ratio = 0.0f64;
    for &g in gs {
        let cfg = SimConfig {
            g,
            b,
            max_steps: steps,
            warmup_steps: steps / 5,
            seed,
            ..SimConfig::default()
        };
        let mut rng = Rng::new(seed ^ g as u64);
        let trace = overloaded_trace(&sampler, g, b, steps, 3.0, &mut rng);
        let sim = Simulator::new(cfg.clone());

        let t = Instant::now();
        let ef = sim.run(&trace, &mut *by_name("fcfs").unwrap());
        let eng_fcfs_ms = ms(t);
        let t = Instant::now();
        let eb = sim.run(&trace, &mut *by_name("bfio:40").unwrap());
        let eng_bfio_ms = ms(t);

        let t = Instant::now();
        let rf = reference_run(&cfg, &Predictor::Oracle, &trace, &mut *by_name("fcfs").unwrap());
        let ref_fcfs_ms = ms(t);
        let t = Instant::now();
        let rb =
            reference_run(&cfg, &Predictor::Oracle, &trace, &mut *by_name("bfio:40").unwrap());
        let ref_bfio_ms = ms(t);

        // the two paths must agree (the full check lives in
        // rust/tests/engine_parity.rs; this guards the bench itself)
        let drift = |a: f64, b: f64| (a - b).abs() / a.abs().max(b.abs()).max(1.0);
        assert!(
            drift(ef.report.avg_imbalance, rf.report.avg_imbalance) < 1e-9,
            "fcfs parity broke at G={g}"
        );
        assert!(
            drift(eb.report.avg_imbalance, rb.report.avg_imbalance) < 1e-9,
            "bfio parity broke at G={g}"
        );

        let speedup = (ref_fcfs_ms + ref_bfio_ms) / (eng_fcfs_ms + eng_bfio_ms).max(1e-9);
        let imb_ratio = ef.report.avg_imbalance / eb.report.avg_imbalance;
        if first_ratio == 0.0 {
            first_ratio = imb_ratio;
        }
        last_ratio = imb_ratio;
        eng_total += eng_fcfs_ms + eng_bfio_ms;
        ref_total += ref_fcfs_ms + ref_bfio_ms;
        println!(
            "{g:>5} {eng_fcfs_ms:>12.1} {eng_bfio_ms:>12.1} {ref_fcfs_ms:>12.1} \
             {ref_bfio_ms:>12.1} {speedup:>8.2}x {imb_ratio:>9.2}x"
        );
        rows_json.push(obj(vec![
            ("g", num(g as f64)),
            ("engine_fcfs_ms", num(eng_fcfs_ms)),
            ("engine_bfio_ms", num(eng_bfio_ms)),
            ("reference_fcfs_ms", num(ref_fcfs_ms)),
            ("reference_bfio_ms", num(ref_bfio_ms)),
            ("speedup", num(speedup)),
            ("fcfs_imb", num(ef.report.avg_imbalance)),
            ("bfio_imb", num(eb.report.avg_imbalance)),
            ("imb_ratio", num(imb_ratio)),
        ]));
    }
    let total_ms = ms(t_all);
    let speedup_overall = ref_total / eng_total.max(1e-9);
    println!(
        "\nimbalance ratio grows {first_ratio:.2}x -> {last_ratio:.2}x; \
         engine is {speedup_overall:.2}x faster than the pre-refactor loop \
         ({eng_total:.0} ms vs {ref_total:.0} ms; {total_ms:.0} ms total)"
    );

    let json = obj(vec![
        ("bench", s("scaling")),
        ("smoke", Json::Bool(smoke)),
        ("b", num(b as f64)),
        ("steps", num(steps as f64)),
        ("seed", num(seed as f64)),
        ("engine_total_ms", num(eng_total)),
        ("reference_total_ms", num(ref_total)),
        ("speedup_overall", num(speedup_overall)),
        ("total_ms", num(total_ms)),
        ("rows", arr(rows_json)),
    ]);
    let default_path =
        if smoke { "BENCH_scaling_smoke.json" } else { "BENCH_scaling.json" };
    let path = out_override.as_deref().unwrap_or(default_path);
    match std::fs::write(path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
