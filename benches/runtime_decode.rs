//! PJRT decode-step latency per KV-capacity variant: the L2 hot path the
//! live coordinator drives every barrier tick.  Requires `make artifacts`.

use bfio_serve::runtime::Runtime;
use bfio_serve::util::bench::Bench;
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("meta.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::load(dir).unwrap();
    let golden = rt.meta.golden.clone();
    let bench = Bench {
        target_time: std::time::Duration::from_secs(1),
        ..Bench::default()
    };
    println!(
        "TinyLM decode step (batch={}, {} params) per KV variant\n",
        rt.meta.decode_batch(),
        rt.meta.n_params
    );

    let caps = rt.meta.decode_capacities();
    for cap in caps {
        let (_, mut state) = rt.prefill_batch(&golden.prompt, cap).unwrap();
        let tokens = golden.next_tokens.clone();
        let r = bench.run(&format!("decode_step/l{cap}"), || {
            // reset positions to keep capacity fixed across iterations
            for p in state.positions.iter_mut() {
                *p = golden.positions[0];
            }
            rt.decode_step(&mut state, &tokens).unwrap()
        });
        let toks = rt.meta.decode_batch() as f64;
        println!(
            "    -> {:.0} tokens/s/worker at this variant",
            toks / (r.mean_ns / 1e9)
        );
    }

    // prefill for comparison
    let cap0 = rt.meta.decode_capacities()[0];
    bench.run("prefill_batch/l64", || {
        rt.prefill_batch(&golden.prompt, cap0).unwrap()
    });
}
