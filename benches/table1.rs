//! End-to-end Table-1 regeneration (the paper's headline table) with
//! timing: workload generation + all nine policy runs.

use bfio_serve::experiments::{table1, ExpScale};
use std::time::Instant;

fn main() {
    let scale = ExpScale {
        g: 64,
        b: 24,
        steps: 400,
        seed: 7,
        out_dir: "results".into(),
    };
    println!(
        "table1 bench: G={} B={} steps={} (use `bfio repro table1 --full` for paper scale)\n",
        scale.g, scale.b, scale.steps
    );
    let t0 = Instant::now();
    let rows = table1(&scale);
    let dt = t0.elapsed().as_secs_f64();
    println!("\nregenerated {} rows in {:.2}s", rows.len(), dt);
}
