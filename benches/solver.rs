//! BF-IO decision latency: the per-step cost of solving (IO) at serving
//! scale.  The paper's requirement is a millisecond decision budget at
//! G=256, B=72 (Section 7.3 "millisecond decision budgets").

use bfio_serve::config::BfIoConfig;
use bfio_serve::policies::bfio::BfIo;
use bfio_serve::policies::{ActiveView, AssignCtx, Policy, WaitingView, WorkerView};
use bfio_serve::util::bench::Bench;
use bfio_serve::util::rng::Rng;
use bfio_serve::workload::Drift;

/// Build a steady-state decision instance: G workers nearly full, a few
/// free slots (the per-step completion count), deep FIFO pool.
fn instance(
    g: usize,
    b: usize,
    free_frac: f64,
    pool: usize,
    seed: u64,
) -> (Vec<WorkerView>, Vec<WaitingView>) {
    let mut rng = Rng::new(seed);
    let workers: Vec<WorkerView> = (0..g)
        .map(|_| {
            let free = if rng.f64() < free_frac { 1 } else { 0 };
            let n = b - free;
            let active: Vec<ActiveView> = (0..n)
                .map(|_| {
                    ActiveView::fresh(500.0 + rng.f64() * 3000.0, 1 + rng.below(200))
                })
                .collect();
            WorkerView {
                load: active.iter().map(|a| a.load).sum(),
                free_slots: free,
                active,
            }
        })
        .collect();
    let waiting: Vec<WaitingView> = (0..pool)
        .map(|i| WaitingView {
            idx: i,
            prefill: 100.0 + rng.f64() * 5000.0,
            arrival_step: 0,
        })
        .collect();
    (workers, waiting)
}

fn main() {
    let bench = Bench::default();
    println!("BF-IO (IO) solver decision latency — paper budget: < 1 ms/step\n");

    for (g, b) in [(64, 24), (256, 72)] {
        for h in [0usize, 40, 100] {
            let (workers, waiting) = instance(g, b, 0.5, 4096, 42);
            let drift = Drift::Unit.cumulative(0, h.max(1));
            let mut policy = BfIo::new(BfIoConfig::with_horizon(h));
            let mut rng = Rng::new(7);
            bench.run(&format!("bfio_decide/g{g}_b{b}_h{h}"), || {
                let ctx = AssignCtx {
                    step: 0,
                    batch_cap: b,
                    workers: &workers,
                    waiting: &waiting,
                    cum_drift: &drift,
                };
                policy.assign(&ctx, &mut rng)
            });
        }
    }

    // Cold-start (empty cluster, G·B admissions at once) — the worst case.
    let (workers, waiting) = {
        let mut rng = Rng::new(3);
        let g = 256;
        let b = 72;
        let workers: Vec<WorkerView> = (0..g)
            .map(|_| WorkerView { load: 0.0, free_slots: b, active: vec![] })
            .collect();
        let waiting: Vec<WaitingView> = (0..g * b)
            .map(|i| WaitingView {
                idx: i,
                prefill: 100.0 + rng.f64() * 5000.0,
                arrival_step: 0,
            })
            .collect();
        (workers, waiting)
    };
    let drift = Drift::Unit.cumulative(0, 1);
    let mut policy = BfIo::new(BfIoConfig::with_horizon(0));
    let mut rng = Rng::new(9);
    Bench::quick().run("bfio_decide/cold_start_g256_b72_18432_reqs", || {
        let ctx = AssignCtx {
            step: 0,
            batch_cap: 72,
            workers: &workers,
            waiting: &waiting,
            cum_drift: &drift,
        };
        policy.assign(&ctx, &mut rng)
    });
}
