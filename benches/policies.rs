//! Full-simulation throughput per policy: how many simulated
//! worker-steps per second the L3 stack sustains (drives the wall-clock
//! of every repro experiment).

use bfio_serve::config::SimConfig;
use bfio_serve::policies::by_name;
use bfio_serve::sim::Simulator;
use bfio_serve::util::bench::Bench;
use bfio_serve::util::rng::Rng;
use bfio_serve::workload::adversarial::overloaded_trace;
use bfio_serve::workload::longbench::LongBenchLike;

fn main() {
    let bench = Bench::quick();
    println!("simulation throughput per policy (G=64, B=24, 200 steps)\n");
    let g = 64;
    let b = 24;
    let steps = 200;
    let sampler = LongBenchLike::paper();
    let mut rng = Rng::new(1);
    let trace = overloaded_trace(&sampler, g, b, steps, 3.0, &mut rng);
    let cfg = SimConfig { g, b, max_steps: steps, seed: 1, ..SimConfig::default() };

    for name in ["fcfs", "jsq", "rr", "pow2", "least", "minmin", "bfio:0", "bfio:40"] {
        let sim = Simulator::new(cfg.clone());
        let r = bench.run(&format!("sim/{name}"), || {
            let mut p = by_name(name).unwrap();
            sim.run(&trace, p.as_mut())
        });
        let worker_steps = (g as f64) * steps as f64;
        println!(
            "    -> {:.1}k worker-steps/s",
            worker_steps / (r.mean_ns / 1e9) / 1e3
        );
    }
}
