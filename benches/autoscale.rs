//! Autoscale sweep: the same diurnal BurstGPT-like trace served by the
//! fleet under {static-R, target-tracking, energy-marginal} scale
//! policies — the machine-readable evidence that closing the loop from
//! the power model to fleet lifecycle lowers energy per token.
//!
//! Emits `BENCH_autoscale.json` (per-policy energy/token, Theorem-4
//! energy decomposition, TPOT, replica-rounds used, action counts, and
//! ratios against the static baseline).  `-- --smoke` runs the CI-size
//! sweep; `-- --out PATH` overrides the output file (CI uses it to
//! regenerate the canonical file with measured numbers).

use bfio_serve::experiments::autoscale::{
    bench_json, rows_to_json, run_autoscale_rows, AutoscaleScale,
};
use std::time::Instant;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let out_override = argv
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let scales: Vec<AutoscaleScale> = if smoke {
        vec![AutoscaleScale::smoke()]
    } else {
        vec![AutoscaleScale::smoke(), AutoscaleScale::full()]
    };
    let policies: Vec<String> = ["static", "target", "energy"]
        .iter()
        .map(|p| p.to_string())
        .collect();

    let t_all = Instant::now();
    let mut sweep = Vec::new();
    for scale in &scales {
        println!(
            "autoscale sweep: {}x({}x{}), {} rounds, diurnal {:.2}..{:.2}/{}",
            scale.replicas,
            scale.g,
            scale.b,
            scale.rounds,
            scale.valley,
            scale.peak,
            scale.period
        );
        let rows = run_autoscale_rows(scale, &policies).expect("autoscale run");
        for r in &rows {
            println!(
                "  {:<16} {:>10.4} J/tok {:>9.4} tpot {:>9} r-rounds \
                 (drn {} rea {} add {})",
                r.policy,
                r.energy_per_token_j,
                r.tpot_s,
                r.replica_rounds,
                r.drains,
                r.reactivations,
                r.adds
            );
        }
        sweep.push(rows_to_json(scale, &rows));
    }
    let total_ms = t_all.elapsed().as_secs_f64() * 1e3;
    println!("total {total_ms:.0} ms");

    // Same document shape as `bfio autoscale`.
    let json = bench_json(smoke, total_ms, sweep);
    let default_path = if smoke {
        "BENCH_autoscale_smoke.json"
    } else {
        "BENCH_autoscale.json"
    };
    let path = out_override.as_deref().unwrap_or(default_path);
    match std::fs::write(path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
