"""L1 correctness: Pallas RMSNorm kernel vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.rmsnorm import rms_norm, rms_norm_ref


def _case(seed, shape, dtype):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, shape, jnp.float32).astype(dtype)
    w = (1.0 + 0.1 * jax.random.normal(k2, shape[-1:], jnp.float32)).astype(dtype)
    return x, w


def _check(x, w, rtol=1e-5, atol=1e-5):
    out = rms_norm(x, w)
    ref = rms_norm_ref(x, w)
    assert out.shape == x.shape
    assert out.dtype == x.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=rtol, atol=atol,
    )


class TestBasic:
    def test_2d(self):
        x, w = _case(0, (4, 32), jnp.float32)
        _check(x, w)

    def test_3d_batch_time(self):
        x, w = _case(1, (2, 8, 16), jnp.float32)
        _check(x, w)

    def test_single_row(self):
        x, w = _case(2, (1, 64), jnp.float32)
        _check(x, w)

    def test_unit_scale_normalizes(self):
        x, _ = _case(3, (8, 128), jnp.float32)
        w = jnp.ones((128,), jnp.float32)
        out = rms_norm(x, w)
        rms = np.sqrt(np.mean(np.square(np.asarray(out)), axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-4)

    def test_bf16(self):
        x, w = _case(4, (4, 32), jnp.bfloat16)
        _check(x, w, rtol=2e-2, atol=2e-2)

    def test_scale_shape_validated(self):
        x, _ = _case(5, (4, 32), jnp.float32)
        with pytest.raises(ValueError):
            rms_norm(x, jnp.ones((16,), jnp.float32))

    def test_jit_compatible(self):
        x, w = _case(6, (4, 32), jnp.float32)
        out = jax.jit(rms_norm)(x, w)
        ref = rms_norm_ref(x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_matches_model_rmsnorm(self):
        # The oracle must agree with the inline implementation in model.py.
        from compile.model import _rms_norm
        x, w = _case(7, (4, 32), jnp.float32)
        a = rms_norm(x, w)
        b = _rms_norm(x, w, 1e-5)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    rows=st.integers(1, 8),
    d=st.sampled_from([8, 16, 32, 64, 128, 256]),
)
def test_hypothesis_f32(seed, rows, d):
    x, w = _case(seed, (rows, d), jnp.float32)
    _check(x, w)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.sampled_from([16, 64, 128]),
)
def test_hypothesis_bf16(seed, d):
    x, w = _case(seed, (4, d), jnp.bfloat16)
    _check(x, w, rtol=3e-2, atol=3e-2)
