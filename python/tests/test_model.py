"""L2 correctness: TinyLM decode/prefill semantics and shape contracts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig, decode_step, init_params, param_specs, prefill,
)

CFG = ModelConfig(vocab=64, d_model=32, n_heads=2, head_dim=16,
                  n_layers=2, d_ff=64)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


def _prompt(b, t, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, CFG.vocab, size=(b, t)), jnp.int32)


class TestSpecs:
    def test_param_count_matches_specs(self, params):
        specs = param_specs(CFG)
        assert len(params) == len(specs)
        for p, (_, shape) in zip(params, specs):
            assert p.shape == shape

    def test_n_params(self):
        total = sum(int(np.prod(s)) for _, s in param_specs(CFG))
        assert CFG.n_params() == total

    def test_ln_initialized_to_ones(self, params):
        specs = param_specs(CFG)
        for p, (name, _) in zip(params, specs):
            if name.endswith(("ln1", "ln2", "ln_f")):
                np.testing.assert_array_equal(np.asarray(p), 1.0)

    def test_init_deterministic(self):
        a = init_params(CFG, seed=0)
        b = init_params(CFG, seed=0)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestPrefill:
    def test_shapes(self, params):
        b, t, cap = 3, 8, 32
        logits, k, v = prefill(params, _prompt(b, t), CFG, cap)
        assert logits.shape == (b, CFG.vocab)
        assert k.shape == (CFG.n_layers, b, cap, CFG.n_heads, CFG.head_dim)
        assert v.shape == k.shape

    def test_cache_zero_beyond_prompt(self, params):
        b, t, cap = 2, 8, 32
        _, k, v = prefill(params, _prompt(b, t), CFG, cap)
        np.testing.assert_array_equal(np.asarray(k[:, :, t:]), 0.0)
        np.testing.assert_array_equal(np.asarray(v[:, :, t:]), 0.0)

    def test_capacity_validation(self, params):
        with pytest.raises(ValueError):
            prefill(params, _prompt(1, 64), CFG, 32)

    def test_padding_invariance(self, params):
        """Same prompt, different KV capacity -> identical logits."""
        b, t = 2, 8
        l32, _, _ = prefill(params, _prompt(b, t), CFG, 32)
        l64, _, _ = prefill(params, _prompt(b, t), CFG, 64)
        np.testing.assert_allclose(np.asarray(l32), np.asarray(l64),
                                   rtol=1e-5, atol=1e-5)


class TestDecode:
    def test_shapes_and_cache_update(self, params):
        b, t, cap = 2, 8, 32
        logits_p, k, v = prefill(params, _prompt(b, t), CFG, cap)
        tok = jnp.argmax(logits_p, axis=-1).astype(jnp.int32)
        pos = jnp.full((b,), t, jnp.int32)
        logits, k2, v2 = decode_step(params, tok, pos, k, v, CFG)
        assert logits.shape == (b, CFG.vocab)
        # new KV written exactly at position t, elsewhere unchanged
        assert not np.allclose(np.asarray(k2[:, :, t]), 0.0)
        np.testing.assert_array_equal(np.asarray(k2[:, :, t + 1:]), 0.0)
        np.testing.assert_allclose(np.asarray(k2[:, :, :t]),
                                   np.asarray(k[:, :, :t]))

    def test_decode_matches_prefill_extension(self, params):
        """prefill(T tokens) + decode(token T) must equal prefill(T+1)."""
        b, t, cap = 2, 8, 32
        prompt = _prompt(b, t + 1, seed=3)
        logits_full, _, _ = prefill(params, prompt, CFG, cap)

        _, k, v = prefill(params, prompt[:, :t], CFG, cap)
        pos = jnp.full((b,), t, jnp.int32)
        logits_dec, _, _ = decode_step(params, prompt[:, t], pos, k, v, CFG)
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_full),
                                   rtol=2e-4, atol=2e-4)

    def test_multi_step_decode_chain(self, params):
        """Three chained decode steps equal one prefill of the full string."""
        b, t, cap, steps = 1, 4, 32, 3
        prompt = _prompt(b, t + steps, seed=7)
        logits_full, _, _ = prefill(params, prompt, CFG, cap)

        _, k, v = prefill(params, prompt[:, :t], CFG, cap)
        logits = None
        for s in range(steps):
            pos = jnp.full((b,), t + s, jnp.int32)
            logits, k, v = decode_step(params, prompt[:, t + s], pos, k, v, CFG)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_full),
                                   rtol=5e-4, atol=5e-4)

    def test_batch_isolation(self, params):
        """Changing one sequence must not change another's logits."""
        b, t, cap = 2, 8, 32
        p1 = _prompt(b, t, seed=1)
        p2 = np.asarray(p1).copy()
        p2[1] = (p2[1] + 7) % CFG.vocab
        p2 = jnp.asarray(p2)

        def run(p):
            logits_p, k, v = prefill(params, p, CFG, cap)
            tok = jnp.argmax(logits_p, axis=-1).astype(jnp.int32)
            pos = jnp.full((b,), t, jnp.int32)
            logits, _, _ = decode_step(params, tok, pos, k, v, CFG)
            return logits

        l1, l2 = run(p1), run(p2)
        np.testing.assert_allclose(np.asarray(l1[0]), np.asarray(l2[0]),
                                   rtol=1e-5, atol=1e-5)
        assert not np.allclose(np.asarray(l1[1]), np.asarray(l2[1]))

    def test_finite_logits(self, params):
        b, t, cap = 4, 8, 64
        logits_p, k, v = prefill(params, _prompt(b, t, seed=9), CFG, cap)
        tok = jnp.argmax(logits_p, axis=-1).astype(jnp.int32)
        pos = jnp.full((b,), t, jnp.int32)
        logits, _, _ = decode_step(params, tok, pos, k, v, CFG)
        assert np.isfinite(np.asarray(logits)).all()
