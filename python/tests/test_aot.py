"""AOT pipeline tests: HLO text artifacts, ABI metadata, golden case."""

import json
import os

import numpy as np
import pytest

from compile.aot import build, golden_case, lower_decode, lower_prefill
from compile.model import ModelConfig, init_params, param_specs

SMALL = ModelConfig(vocab=32, d_model=16, n_heads=2, head_dim=8,
                    n_layers=1, d_ff=32)


def _entry_params(text: str) -> int:
    """Count parameter() instructions in the ENTRY computation only."""
    in_entry = False
    count = 0
    for ln in text.splitlines():
        if ln.startswith("ENTRY "):
            in_entry = True
            continue
        if in_entry:
            if ln.startswith("}"):
                break
            if " parameter(" in ln:
                count += 1
    return count


class TestLowering:
    def test_decode_hlo_text_wellformed(self):
        text = lower_decode(SMALL, batch=2, kv_cap=16)
        assert "ENTRY" in text and "HloModule" in text
        # one tensor parameter per model param + 4 runtime inputs
        assert _entry_params(text) == len(param_specs(SMALL)) + 4

    def test_prefill_hlo_text_wellformed(self):
        text = lower_prefill(SMALL, batch=2, t=4, kv_cap=16)
        assert "ENTRY" in text
        assert _entry_params(text) == len(param_specs(SMALL)) + 1

    def test_hlo_is_text_not_proto(self):
        """Guard the interchange-format decision (DESIGN.md): HLO text."""
        text = lower_decode(SMALL, batch=1, kv_cap=16)
        assert text.lstrip().startswith("HloModule")


class TestBuild:
    @pytest.fixture(scope="class")
    def built(self, tmp_path_factory):
        out = str(tmp_path_factory.mktemp("artifacts"))
        meta = build(out, SMALL, batch=2, t=4, kv_variants=(16, 32))
        return out, meta

    def test_files_exist(self, built):
        out, meta = built
        for a in meta["artifacts"]:
            assert os.path.exists(os.path.join(out, a["file"]))
        assert os.path.exists(os.path.join(out, "params.bin"))
        assert os.path.exists(os.path.join(out, "golden.bin"))
        assert os.path.exists(os.path.join(out, "meta.json"))

    def test_params_bin_size(self, built):
        out, meta = built
        n = meta["model"]["n_params"]
        assert os.path.getsize(os.path.join(out, "params.bin")) == 4 * n
        assert n == SMALL.n_params()

    def test_param_offsets_contiguous(self, built):
        _, meta = built
        off = 0
        for p in meta["params"]:
            assert p["offset"] == off
            off += int(np.prod(p["shape"]))
        assert off == meta["model"]["n_params"]

    def test_golden_shape(self, built):
        out, meta = built
        g = meta["golden"]
        logits = np.fromfile(os.path.join(out, "golden.bin"), dtype=np.float32)
        assert logits.size == int(np.prod(g["logits_shape"]))
        assert np.isfinite(logits).all()

    def test_incremental_skip(self, built, capsys):
        out, _ = built
        build(out, SMALL, batch=2, t=4, kv_variants=(16, 32))
        assert "up-to-date" in capsys.readouterr().out

    def test_force_rebuild_reproducible(self, built):
        out, meta = built
        before = np.fromfile(os.path.join(out, "golden.bin"), np.float32)
        build(out, SMALL, batch=2, t=4, kv_variants=(16, 32), force=True)
        after = np.fromfile(os.path.join(out, "golden.bin"), np.float32)
        np.testing.assert_allclose(before, after, rtol=1e-6, atol=1e-6)


class TestGolden:
    def test_golden_case_deterministic(self):
        params = init_params(SMALL)
        a = golden_case(SMALL, params, batch=2, t=4, kv_cap=16)
        b = golden_case(SMALL, params, batch=2, t=4, kv_cap=16)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_allclose(a[3], b[3], rtol=1e-6, atol=1e-6)

    def test_golden_capacity_invariance(self):
        """Golden logits must not depend on the KV padding capacity."""
        params = init_params(SMALL)
        a = golden_case(SMALL, params, batch=2, t=4, kv_cap=16)
        b = golden_case(SMALL, params, batch=2, t=4, kv_cap=32)
        np.testing.assert_allclose(a[3], b[3], rtol=1e-4, atol=1e-4)
