"""L1 correctness: Pallas decode-attention kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel layer — hypothesis
sweeps shapes, dtypes, chunk sizes, and resident lengths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention, vmem_bytes
from compile.kernels.ref import decode_attention_ref


def _rand_case(seed, b, l, h, d, dtype):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, l, h, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, l, h, d), jnp.float32).astype(dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, l + 1).astype(jnp.int32)
    return q, k, v, lengths


def _check(q, k, v, lengths, chunk=None, rtol=1e-5, atol=1e-5):
    out = decode_attention(q, k, v, lengths, chunk=chunk)
    ref = decode_attention_ref(q, k, v, lengths)
    assert out.shape == ref.shape
    assert out.dtype == q.dtype
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32),
        np.asarray(ref, dtype=np.float32),
        rtol=rtol, atol=atol,
    )


class TestBasic:
    def test_single_sequence_full_length(self):
        q, k, v, _ = _rand_case(0, 1, 32, 2, 16, jnp.float32)
        _check(q, k, v, jnp.array([32], jnp.int32))

    def test_length_one(self):
        """Only the first KV entry is resident -> output == v[:, 0]."""
        q, k, v, _ = _rand_case(1, 2, 16, 2, 8, jnp.float32)
        lengths = jnp.array([1, 1], jnp.int32)
        out = decode_attention(q, k, v, lengths)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(v[:, 0]), rtol=1e-6, atol=1e-6)

    def test_mixed_lengths(self):
        q, k, v, _ = _rand_case(2, 4, 64, 4, 32, jnp.float32)
        lengths = jnp.array([1, 13, 40, 64], jnp.int32)
        _check(q, k, v, lengths)

    def test_single_head(self):
        q, k, v, lengths = _rand_case(3, 2, 32, 1, 8, jnp.float32)
        _check(q, k, v, lengths)

    def test_chunk_boundary_lengths(self):
        """Resident length exactly at / around a chunk boundary."""
        q, k, v, _ = _rand_case(4, 3, 64, 2, 16, jnp.float32)
        for lens in ([16, 17, 15], [32, 33, 31], [64, 48, 1]):
            _check(q, k, v, jnp.array(lens, jnp.int32), chunk=16)

    def test_explicit_chunk_sizes(self):
        q, k, v, lengths = _rand_case(5, 2, 48, 2, 16, jnp.float32)
        for chunk in (1, 2, 4, 8, 16, 24, 48):
            _check(q, k, v, lengths, chunk=chunk)

    def test_chunk_must_divide(self):
        q, k, v, lengths = _rand_case(6, 1, 48, 1, 8, jnp.float32)
        with pytest.raises(ValueError):
            decode_attention(q, k, v, lengths, chunk=13)

    def test_bf16(self):
        q, k, v, lengths = _rand_case(7, 3, 64, 4, 32, jnp.bfloat16)
        _check(q, k, v, lengths, rtol=3e-2, atol=3e-2)

    def test_mask_ignores_padding_garbage(self):
        """Entries beyond `length` must not affect the result."""
        q, k, v, _ = _rand_case(8, 2, 32, 2, 16, jnp.float32)
        lengths = jnp.array([10, 20], jnp.int32)
        out1 = decode_attention(q, k, v, lengths)
        k2 = k.at[:, 25:].set(1e4)
        v2 = v.at[:, 25:].set(-1e4)
        out2 = decode_attention(q, k2, v2, lengths)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))

    def test_large_logit_stability(self):
        """Online softmax must be stable for large-magnitude logits."""
        q, k, v, lengths = _rand_case(9, 2, 32, 2, 16, jnp.float32)
        q = q * 100.0
        out = decode_attention(q, k, v, lengths, chunk=8)
        assert np.isfinite(np.asarray(out)).all()
        _check(q, k, v, lengths, chunk=8, rtol=1e-4, atol=1e-4)

    def test_jit_compatible(self):
        q, k, v, lengths = _rand_case(10, 2, 32, 2, 16, jnp.float32)
        jitted = jax.jit(lambda *a: decode_attention(*a))
        out = jitted(q, k, v, lengths)
        ref = decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_vmem_estimate_monotone(self):
        assert vmem_bytes(256, 4, 32) > vmem_bytes(128, 4, 32)
        # default config, bf16: well under the 16 MiB/core VMEM budget
        assert vmem_bytes(2048, 4, 128, 2) < 16 * 2**20


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32, 64]),
    l_total=st.sampled_from([8, 16, 32, 64, 128]),
)
def test_hypothesis_f32(seed, b, h, d, l_total):
    q, k, v, lengths = _rand_case(seed, b, l_total, h, d, jnp.float32)
    _check(q, k, v, lengths)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 3),
    h=st.sampled_from([1, 4]),
    d=st.sampled_from([16, 32]),
    l_total=st.sampled_from([16, 64]),
    chunk_div=st.sampled_from([1, 2, 4]),
)
def test_hypothesis_chunks(seed, b, h, d, l_total, chunk_div):
    q, k, v, lengths = _rand_case(seed, b, l_total, h, d, jnp.float32)
    _check(q, k, v, lengths, chunk=l_total // chunk_div)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 3),
    l_total=st.sampled_from([16, 64]),
)
def test_hypothesis_bf16(seed, b, l_total):
    q, k, v, lengths = _rand_case(seed, b, l_total, 2, 32, jnp.bfloat16)
    _check(q, k, v, lengths, rtol=5e-2, atol=5e-2)
