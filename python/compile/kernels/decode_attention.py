"""L1 Pallas kernel: batched decode attention over a padded KV cache.

This is the paper's decode-stage hot spot: at each decode step, worker g
computes attention for its batch of requests; the local runtime
``T_local^(g)`` is linear in the aggregate *resident* KV it must read
(Section 1 of the paper).  One query token per sequence attends over that
sequence's resident KV prefix.

TPU adaptation (DESIGN.md section "Hardware adaptation"):
  * the grid iterates over the batch; BlockSpec streams one sequence's
    KV from HBM into VMEM per grid step (the TPU analogue of the GPU
    threadblock tiling the paper's A100 testbed would use),
  * inside the kernel the VMEM-resident KV is consumed in ``CHUNK``-sized
    tiles with an online-softmax (flash-decoding) recurrence, so the
    working set per iteration is MXU-friendly ``[CHUNK, H*D]`` tiles,
  * contractions run through ``lax.dot_general`` with
    ``preferred_element_type=float32`` so bf16 inputs accumulate in f32
    on the MXU.

The kernel MUST be lowered with ``interpret=True`` on this image: the CPU
PJRT plugin cannot execute Mosaic custom-calls (see /opt/xla-example
README).  Correctness is pinned against the pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Finite stand-in for -inf: keeps the online-softmax recurrence NaN-free
# when an entire chunk is masked out (exp(-1e30 - m) underflows to 0).
_NEG_INF = -1.0e30


def _attention_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, chunk: int):
    """Single-sequence decode attention with online softmax.

    Block shapes (leading batch-block dim of 1 squeezed below):
      q_ref: [1, H, D]   k_ref/v_ref: [1, L, H, D]   len_ref: [1]
      o_ref: [1, H, D]
    """
    q = q_ref[0].astype(jnp.float32)  # [H, D]
    h, d = q.shape
    l_total = k_ref.shape[1]
    length = len_ref[0]
    scale = 1.0 / math.sqrt(d)

    n_chunks = l_total // chunk

    def body(i, carry):
        m, s, acc = carry  # [H], [H], [H, D]
        start = i * chunk
        k = pl.load(k_ref, (0, pl.ds(start, chunk), slice(None), slice(None)))
        v = pl.load(v_ref, (0, pl.ds(start, chunk), slice(None), slice(None)))
        k = k.astype(jnp.float32)  # [C, H, D]
        v = v.astype(jnp.float32)

        # logits[h, c] = sum_d q[h, d] * k[c, h, d]  — MXU contraction.
        logits = lax.dot_general(
            q, k,
            dimension_numbers=(((1,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        ) * scale  # [H, C]

        pos = start + lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
        mask = pos < length  # [1, C]
        logits = jnp.where(mask, logits, _NEG_INF)

        m_new = jnp.maximum(m, jnp.max(logits, axis=1))  # [H]
        p = jnp.exp(logits - m_new[:, None])  # [H, C]
        corr = jnp.exp(m - m_new)  # [H]
        s_new = s * corr + jnp.sum(p, axis=1)
        # acc[h, d] += sum_c p[h, c] * v[c, h, d]
        pv = lax.dot_general(
            p, v,
            dimension_numbers=(((1,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        )  # [H, D]
        acc_new = acc * corr[:, None] + pv
        return m_new, s_new, acc_new

    m0 = jnp.full((h,), _NEG_INF, dtype=jnp.float32)
    s0 = jnp.zeros((h,), dtype=jnp.float32)
    acc0 = jnp.zeros((h, d), dtype=jnp.float32)
    m, s, acc = lax.fori_loop(0, n_chunks, body, (m0, s0, acc0))

    out = acc / s[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, chunk: int | None = None):
    """Batched decode attention via the Pallas kernel (interpret mode).

    Args:
      q: [B, H, D] query for the single new token of each sequence.
      k_cache: [B, L, H, D] padded key cache.
      v_cache: [B, L, H, D] padded value cache.
      lengths: [B] int32, resident KV length per sequence (1 <= len <= L).
      chunk: KV tile size; defaults to min(128, L); must divide L.

    Returns:
      [B, H, D] attention output, in q.dtype.
    """
    b, h, d = q.shape
    l_total = k_cache.shape[1]
    if chunk is None:
        chunk = min(128, l_total)
    if l_total % chunk != 0:
        raise ValueError(f"chunk {chunk} must divide KV capacity {l_total}")
    lengths = lengths.astype(jnp.int32)

    kernel = functools.partial(_attention_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, l_total, h, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, l_total, h, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=True,
    )(q, k_cache, v_cache, lengths)


def vmem_bytes(l_total: int, h: int, d: int, dtype_bytes: int = 2) -> int:
    """Estimated VMEM footprint of one grid step (K+V blocks + q/o)."""
    kv = 2 * l_total * h * d * dtype_bytes
    qo = 2 * h * d * 4
    return kv + qo
