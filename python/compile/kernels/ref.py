"""Pure-jnp oracle for the Pallas decode-attention kernel.

This is the CORE correctness signal for Layer 1: ``pytest python/tests``
sweeps shapes/dtypes (hypothesis) and asserts the Pallas kernel matches
this reference to tight tolerances.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

_NEG_INF = -1.0e30


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """Masked single-token attention, straightforward softmax.

    Args:
      q: [B, H, D]; k_cache/v_cache: [B, L, H, D]; lengths: [B] int.
    Returns:
      [B, H, D] in q.dtype.
    """
    b, h, d = q.shape
    l_total = k_cache.shape[1]
    qf = q.astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)

    # logits[b, h, l] = q[b, h, :] . k[b, l, h, :]
    logits = jnp.einsum("bhd,blhd->bhl", qf, kf) / math.sqrt(d)
    mask = jnp.arange(l_total)[None, None, :] < lengths[:, None, None]
    logits = jnp.where(mask, logits, _NEG_INF)
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("bhl,blhd->bhd", w, vf)
    return out.astype(q.dtype)


def causal_attention_ref(q, k, v):
    """Full causal self-attention for the prefill path.

    Args:
      q, k, v: [B, T, H, D].
    Returns:
      [B, T, H, D] in q.dtype.
    """
    b, t, h, d = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) / math.sqrt(d)
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(causal[None, None, :, :], logits, _NEG_INF)
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vf)
    return out.astype(q.dtype)
