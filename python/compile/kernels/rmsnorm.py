"""L1 Pallas kernel: RMSNorm over the model dimension.

Used by TinyLM at every layer boundary (two per block plus the final
norm), so it sits on the decode hot path together with the attention
kernel.  TPU shaping: the grid iterates over rows (batch elements or
batch×time positions); each grid step streams one `[1, D]` row through
VMEM, reduces in f32, and scales — a pure VPU kernel (no MXU), fused into
the surrounding HLO at AOT time.

interpret=True as required on this image (CPU PJRT, no Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[0].astype(jnp.float32)  # [D]
    w = w_ref[...].astype(jnp.float32)  # [D]
    var = jnp.mean(jnp.square(x))
    y = x * jax.lax.rsqrt(var + eps) * w
    o_ref[0] = y.astype(o_ref.dtype)


def rms_norm(x, w, *, eps: float = 1e-5):
    """RMSNorm along the last axis via Pallas.

    Args:
      x: [..., D] activations (any leading shape; flattened to rows).
      w: [D] scale.
    Returns:
      same shape/dtype as x.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    if w.shape != (d,):
        raise ValueError(f"scale shape {w.shape} != ({d},)")
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)

    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x2, w)
    return out.reshape(orig_shape)


def rms_norm_ref(x, w, *, eps: float = 1e-5):
    """Pure-jnp oracle."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)
