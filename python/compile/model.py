"""L2: TinyLM — a small decoder-only transformer for the serving stack.

The decode step (one token per active sequence, attention over the padded
per-sequence KV cache via the L1 Pallas kernel) is the compute that runs on
every simulated "GPU worker" in the Rust coordinator.  Both ``prefill`` and
``decode_step`` are lowered to HLO text by ``aot.py`` and executed from Rust
through PJRT; Python never runs at serving time.

Parameter layout is a *flat list* (see ``param_specs``) so the Rust side can
feed PJRT inputs positionally from ``params.bin`` without a pytree library.

Weights are randomly initialized (deterministic seed).  A pretrained
checkpoint is not available offline; for the paper's purposes the serving
load is architecture-shaped (attention cost linear in resident KV), which
random weights exercise identically — see DESIGN.md "Substitutions".
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels.decode_attention import decode_attention
from .kernels.ref import causal_attention_ref
from .kernels.rmsnorm import rms_norm


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    head_dim: int = 32
    n_layers: int = 2
    d_ff: int = 256
    eps: float = 1e-5

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    def n_params(self) -> int:
        return sum(int(math.prod(s)) for _, s in param_specs(self))


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the ABI between aot.py and Rust."""
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.ln1", (cfg.d_model,)),
            (f"l{i}.wq", (cfg.d_model, cfg.qkv_dim)),
            (f"l{i}.wk", (cfg.d_model, cfg.qkv_dim)),
            (f"l{i}.wv", (cfg.d_model, cfg.qkv_dim)),
            (f"l{i}.wo", (cfg.qkv_dim, cfg.d_model)),
            (f"l{i}.ln2", (cfg.d_model,)),
            (f"l{i}.w_gate", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.w_up", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.w_down", (cfg.d_ff, cfg.d_model)),
        ]
    specs.append(("ln_f", (cfg.d_model,)))
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Deterministic scaled-normal init, flat list in param_specs order."""
    key = jax.random.PRNGKey(seed)
    params: List[jax.Array] = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = 1.0 / math.sqrt(fan_in)
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


def _rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _unpack(params: Sequence[jax.Array], cfg: ModelConfig):
    """Group the flat list into per-layer tuples."""
    embed = params[0]
    layers = []
    idx = 1
    for _ in range(cfg.n_layers):
        layers.append(tuple(params[idx:idx + 9]))
        idx += 9
    ln_f = params[idx]
    return embed, layers, ln_f


def decode_step(
    params: Sequence[jax.Array],
    token_ids: jax.Array,     # [B] int32
    positions: jax.Array,     # [B] int32 — write index == current resident len
    k_cache: jax.Array,       # [n_layers, B, L, H, Dh]
    v_cache: jax.Array,       # [n_layers, B, L, H, Dh]
    cfg: ModelConfig,
):
    """One barrier-synchronized decode step for a batch of B sequences.

    Writes this step's K/V at ``positions`` and attends over
    ``positions + 1`` resident entries (the new token included), exactly the
    "+1 KV growth per decode step" workload model of the paper (Section 3).

    Returns (logits [B, vocab], k_cache', v_cache').
    """
    embed, layers, ln_f = _unpack(params, cfg)
    b = token_ids.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    lengths = positions + 1

    x = embed[token_ids]  # [B, D]
    new_k = k_cache
    new_v = v_cache
    for li, (ln1, wq, wk, wv, wo, ln2, w_gate, w_up, w_down) in enumerate(layers):
        y = rms_norm(x, ln1, eps=cfg.eps)  # L1 Pallas kernel
        q = (y @ wq).reshape(b, h, dh)
        k = (y @ wk).reshape(b, h, dh)
        v = (y @ wv).reshape(b, h, dh)

        # Scatter this step's K/V into the cache at per-sequence positions.
        def write(cache_l, kv, pos):
            return jax.vmap(
                lambda c, t, p: jax.lax.dynamic_update_slice(c, t[None], (p, 0, 0))
            )(cache_l, kv, pos)

        k_l = write(new_k[li], k, positions)  # [B, L, H, Dh]
        v_l = write(new_v[li], v, positions)
        new_k = new_k.at[li].set(k_l)
        new_v = new_v.at[li].set(v_l)

        attn = decode_attention(q, k_l, v_l, lengths)  # [B, H, Dh] (Pallas)
        x = x + attn.reshape(b, h * dh) @ wo

        y = rms_norm(x, ln2, eps=cfg.eps)
        x = x + (jax.nn.silu(y @ w_gate) * (y @ w_up)) @ w_down

    x = rms_norm(x, ln_f, eps=cfg.eps)
    logits = x @ embed.T  # tied head
    return logits, new_k, new_v


def prefill(
    params: Sequence[jax.Array],
    token_ids: jax.Array,   # [B, T] int32
    cfg: ModelConfig,
    kv_capacity: int,
):
    """Encode a length-T prompt per sequence; emit logits of the last token
    and a KV cache padded to ``kv_capacity``.

    Returns (logits [B, vocab], k_cache, v_cache) with caches
    [n_layers, B, kv_capacity, H, Dh].
    """
    embed, layers, ln_f = _unpack(params, cfg)
    b, t = token_ids.shape
    h, dh = cfg.n_heads, cfg.head_dim
    if t > kv_capacity:
        raise ValueError(f"prompt length {t} exceeds KV capacity {kv_capacity}")

    x = embed[token_ids]  # [B, T, D]
    ks, vs = [], []
    for (ln1, wq, wk, wv, wo, ln2, w_gate, w_up, w_down) in layers:
        y = _rms_norm(x, ln1, cfg.eps)
        q = (y @ wq).reshape(b, t, h, dh)
        k = (y @ wk).reshape(b, t, h, dh)
        v = (y @ wv).reshape(b, t, h, dh)
        attn = causal_attention_ref(q, k, v)  # [B, T, H, Dh]
        x = x + attn.reshape(b, t, h * dh) @ wo
        y = _rms_norm(x, ln2, cfg.eps)
        x = x + (jax.nn.silu(y @ w_gate) * (y @ w_up)) @ w_down
        pad = ((0, 0), (0, kv_capacity - t), (0, 0), (0, 0))
        ks.append(jnp.pad(k, pad))
        vs.append(jnp.pad(v, pad))

    x = _rms_norm(x, ln_f, cfg.eps)
    logits = x[:, -1, :] @ embed.T
    return logits, jnp.stack(ks), jnp.stack(vs)
