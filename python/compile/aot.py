"""AOT pipeline: lower TinyLM prefill/decode to HLO text for the Rust runtime.

Run once at build time (``make artifacts``); Python never runs at serving
time.  Interchange format is **HLO text**, NOT ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (in --out-dir, default ../artifacts):
  decode_b{B}_l{L}.hlo.txt    one decode step per KV-capacity variant
  prefill_b{B}_t{T}_l{L}.hlo.txt
  params.bin                  flat little-endian f32 params, param_specs order
  golden.bin                  expected decode-step logits [B, vocab] f32
  meta.json                   model config, ABI, artifact index, golden inputs

KV-capacity variants: the Rust coordinator picks the smallest variant whose
capacity covers a worker's maximal resident length, so heavier-loaded
workers genuinely run a larger attention computation — the load-dependent
``T_local^(g)`` of the paper, realized with static XLA shapes.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import ModelConfig, decode_step, init_params, param_specs, prefill

DEFAULT_KV_VARIANTS = (64, 128, 256)
DEFAULT_BATCH = 4
DEFAULT_PREFILL_T = 16
GOLDEN_SEED = 0


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_decode(cfg: ModelConfig, batch: int, kv_cap: int) -> str:
    n_args = len(param_specs(cfg))
    cache_shape = (cfg.n_layers, batch, kv_cap, cfg.n_heads, cfg.head_dim)

    def fn(*args):
        params = list(args[:n_args])
        token_ids, positions, k_cache, v_cache = args[n_args:]
        return decode_step(params, token_ids, positions, k_cache, v_cache, cfg)

    example = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in param_specs(cfg)]
    example += [
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct(cache_shape, jnp.float32),
        jax.ShapeDtypeStruct(cache_shape, jnp.float32),
    ]
    return to_hlo_text(jax.jit(fn).lower(*example))


def lower_prefill(cfg: ModelConfig, batch: int, t: int, kv_cap: int) -> str:
    n_args = len(param_specs(cfg))

    def fn(*args):
        params = list(args[:n_args])
        token_ids = args[n_args]
        return prefill(params, token_ids, cfg, kv_cap)

    example = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in param_specs(cfg)]
    example += [jax.ShapeDtypeStruct((batch, t), jnp.int32)]
    return to_hlo_text(jax.jit(fn).lower(*example))


def golden_case(cfg: ModelConfig, params: List[jax.Array], batch: int,
                t: int, kv_cap: int):
    """Reference trajectory: prefill a prompt, then one decode step.

    The Rust integration test replays exactly this through the compiled
    artifacts and checks logits against golden.bin.
    """
    rng = np.random.RandomState(GOLDEN_SEED)
    prompt = rng.randint(0, cfg.vocab, size=(batch, t)).astype(np.int32)
    logits_p, k_cache, v_cache = prefill(params, jnp.asarray(prompt), cfg, kv_cap)
    next_tokens = np.asarray(jnp.argmax(logits_p, axis=-1), dtype=np.int32)
    positions = np.full((batch,), t, dtype=np.int32)
    logits_d, _, _ = decode_step(
        params, jnp.asarray(next_tokens), jnp.asarray(positions),
        k_cache, v_cache, cfg,
    )
    return prompt, next_tokens, positions, np.asarray(logits_d, dtype=np.float32)


def build(out_dir: str, cfg: ModelConfig, batch: int, t: int,
          kv_variants=DEFAULT_KV_VARIANTS, force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    meta_path = os.path.join(out_dir, "meta.json")

    # Incremental: skip if inputs unchanged (make-level check also exists).
    # The fingerprint covers the config AND the compile-path sources, so
    # editing a kernel or the model forces a rebuild.
    src_dir = os.path.dirname(os.path.abspath(__file__))
    code = hashlib.sha256()
    for root, _, files in sorted(os.walk(src_dir)):
        for fname in sorted(files):
            if fname.endswith(".py"):
                with open(os.path.join(root, fname), "rb") as f:
                    code.update(f.read())
    fingerprint = hashlib.sha256(
        json.dumps([cfg.__dict__, batch, t, list(kv_variants),
                    code.hexdigest()], sort_keys=True).encode()
    ).hexdigest()
    if not force and os.path.exists(meta_path):
        try:
            with open(meta_path) as f:
                old = json.load(f)
            if old.get("fingerprint") == fingerprint:
                print(f"artifacts up-to-date in {out_dir} (fingerprint match)")
                return old
        except (json.JSONDecodeError, OSError):
            pass

    params = init_params(cfg)
    flat = np.concatenate([np.asarray(p, dtype=np.float32).ravel() for p in params])
    flat.tofile(os.path.join(out_dir, "params.bin"))

    artifacts = []
    for kv_cap in kv_variants:
        name = f"decode_b{batch}_l{kv_cap}"
        text = lower_decode(cfg, batch, kv_cap)
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        artifacts.append({"name": name, "kind": "decode", "batch": batch,
                          "kv_capacity": kv_cap, "file": f"{name}.hlo.txt"})
        print(f"wrote {name}.hlo.txt ({len(text)} chars)")

        pname = f"prefill_b{batch}_t{t}_l{kv_cap}"
        ptext = lower_prefill(cfg, batch, t, kv_cap)
        with open(os.path.join(out_dir, f"{pname}.hlo.txt"), "w") as f:
            f.write(ptext)
        artifacts.append({"name": pname, "kind": "prefill", "batch": batch,
                          "prompt_len": t, "kv_capacity": kv_cap,
                          "file": f"{pname}.hlo.txt"})
        print(f"wrote {pname}.hlo.txt ({len(ptext)} chars)")

    prompt, next_tokens, positions, logits = golden_case(
        cfg, params, batch, t, kv_variants[0])
    logits.tofile(os.path.join(out_dir, "golden.bin"))

    specs = param_specs(cfg)
    offsets, off = [], 0
    for _, shape in specs:
        n = int(np.prod(shape))
        offsets.append(off)
        off += n

    meta = {
        "fingerprint": fingerprint,
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "head_dim": cfg.head_dim,
            "n_layers": cfg.n_layers, "d_ff": cfg.d_ff,
            "n_params": int(flat.size),
        },
        "params": [
            {"name": name, "shape": list(shape), "offset": offsets[i]}
            for i, (name, shape) in enumerate(specs)
        ],
        "artifacts": artifacts,
        "golden": {
            "kv_capacity": kv_variants[0],
            "prompt": prompt.tolist(),
            "next_tokens": next_tokens.tolist(),
            "positions": positions.tolist(),
            "logits_file": "golden.bin",
            "logits_shape": [batch, cfg.vocab],
            "rtol": 2e-4,
            "atol": 2e-4,
        },
    }
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote meta.json, params.bin ({flat.size} f32), golden.bin")
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--prefill-len", type=int, default=DEFAULT_PREFILL_T)
    ap.add_argument("--kv-variants", type=int, nargs="+",
                    default=list(DEFAULT_KV_VARIANTS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    cfg = ModelConfig()
    build(args.out_dir, cfg, args.batch, args.prefill_len,
          tuple(args.kv_variants), force=args.force)


if __name__ == "__main__":
    main()
